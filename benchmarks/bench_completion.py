"""Paper Fig. 5 + Table III: job completion time per scheme under stragglers.

Protocol (Section V): N workers, s of them slowed by a background load;
master collects until decodable, then decodes.  Compute time is event-driven
simulation charged from each scheme's per-worker cost factor; decode time is
measured for real on actual sparse blocks.  Data = the paper's square / tall
/ fat random sparse matrices, dimension-scaled to the CPU budget (density
regime preserved; see repro.configs.sparse_code_demo).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, sparse_bernoulli
from repro.configs.sparse_code_demo import BENCH_FAT, BENCH_SQUARE, BENCH_TALL
from repro.core import schemes
from repro.core.decoder import DecodingError
from repro.core.encoder import split_blocks, compute_block_products
from repro.runtime import SlowWorkers, run_coded_job

SCHEME_ORDER = ["uncoded", "lt_code", "sparse_mds", "product", "polynomial",
                "sparse_code", "sparse_code_opt"]

# the paper's experiments use the LP-optimized degree distribution (model
# (46) / Table IV) at these small mn -- that is the headline row; the wave
# soliton row shows the asymptotic design's constant.
CTORS = dict(schemes.SCHEMES)
CTORS["sparse_code_opt"] = lambda m, n, N, seed=0: schemes.sparse_code(
    m, n, N, distribution="optimized", seed=seed)


def _make_blocks(exp, rng):
    A = sparse_bernoulli(rng, exp.s, exp.r - exp.r % exp.m, exp.nnz_a)
    B = sparse_bernoulli(rng, exp.s, exp.t - exp.t % exp.n, exp.nnz_b)
    A_blocks = split_blocks(A, exp.m)
    B_blocks = split_blocks(B, exp.n)
    prods = compute_block_products(A_blocks, B_blocks)
    return [prods[i][j] for i in range(exp.m) for j in range(exp.n)]


def run(quick: bool = True):
    """Reproduction note (EXPERIMENTS.md): coded schemes beat uncoded only
    when the straggler slowdown exceeds the coded scheme's effective degree
    (~3-5 for the sparse code at mn=16).  The paper's background-load
    stragglers are severe (uncoded/sparse ~ 3x in Table III); we report a
    moderate (5x) and a severe (10x) regime."""
    rows = []
    datasets = [("square", BENCH_SQUARE), ("tall", BENCH_TALL), ("fat", BENCH_FAT)]
    trials = 3 if quick else 20
    slowdowns = (5.0, 10.0)
    for dname, exp in [d for d in datasets]:
        rng = np.random.default_rng(7)
        blocks = _make_blocks(exp, rng)
        m, n, N = exp.m, exp.n, exp.num_workers + 12
        for slow in slowdowns:
            _bench_one(rows, f"{dname}/slow{slow:g}x", blocks, m, n, N,
                       SlowWorkers(num_slow=exp.num_stragglers, slowdown=slow),
                       trials)
    return rows


def _bench_one(rows, dname, blocks, m, n, N, strag, trials):
        for sname in SCHEME_ORDER:
            ctor = CTORS[sname]
            totals, decodes, waited, failed = [], [], [], 0
            for t in range(trials):
                code = ctor(m, n) if sname == "uncoded" else ctor(m, n, N, seed=t)
                try:
                    rep = run_coded_job(code, blocks, strag,
                                        rng=np.random.default_rng(100 + t),
                                        unit_block_time=0.05)
                except DecodingError:
                    failed += 1  # LT peeling can stall even with all workers
                    continue
                totals.append(rep.total_time)
                decodes.append(rep.decode_wall_time)
                waited.append(rep.workers_used)
            if not totals:
                rows.append(Row(f"tableIII/{dname}/{sname}", 0.0,
                                f"UNDECODABLE in {failed}/{trials} trials"))
                continue
            note = f" failed={failed}/{trials}" if failed else ""
            rows.append(Row(
                f"tableIII/{dname}/{sname}", float(np.mean(totals)) * 1e6,
                f"total={np.mean(totals):.4f}s decode={np.mean(decodes):.4f}s "
                f"workers={np.mean(waited):.1f}/"
                f"{N if sname != 'uncoded' else m*n}{note}"))
