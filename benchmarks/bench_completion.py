"""Paper Fig. 5 + Table III: job completion time per scheme under stragglers.

Protocol (Section V): N workers, s of them slowed by a background load;
master collects until decodable, then decodes.  Compute time is event-driven
simulation charged from each scheme's per-worker cost factor; decode time is
measured for real on actual sparse blocks.  Data = the paper's square / tall
/ fat random sparse matrices, dimension-scaled to the CPU budget (density
regime preserved; see repro.configs.sparse_code_demo).

Beyond the paper: the chunked-vs-atomic sweep (`_chunked_sweep`) measures
the partial-straggler protocol (DESIGN.md section 8) at equal total work --
q ordered sub-tasks per worker, master decodes from completed chunks -- and
persists the result into BENCH_coded_matmul.json (merged, never clobbering
the SPMD suite's keys) so CI tracks the chunked speedup as an artifact.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, merge_into_bench_json, sparse_bernoulli
from repro.configs.sparse_code_demo import BENCH_FAT, BENCH_SQUARE, BENCH_TALL
from repro.core import schemes
from repro.core.decoder import DecodingError
from repro.core.encoder import split_blocks, compute_block_products
from repro.runtime import SlowWorkers, run_coded_job

SCHEME_ORDER = ["uncoded", "lt_code", "sparse_mds", "product", "polynomial",
                "sparse_code", "sparse_code_opt"]

# the paper's experiments use the LP-optimized degree distribution (model
# (46) / Table IV) at these small mn -- that is the headline row; the wave
# soliton row shows the asymptotic design's constant.
CTORS = dict(schemes.SCHEMES)
CTORS["sparse_code_opt"] = lambda m, n, N, seed=0: schemes.sparse_code(
    m, n, N, distribution="optimized", seed=seed)


def _make_blocks(exp, rng):
    A = sparse_bernoulli(rng, exp.s, exp.r - exp.r % exp.m, exp.nnz_a)
    B = sparse_bernoulli(rng, exp.s, exp.t - exp.t % exp.n, exp.nnz_b)
    A_blocks = split_blocks(A, exp.m)
    B_blocks = split_blocks(B, exp.n)
    prods = compute_block_products(A_blocks, B_blocks)
    return [prods[i][j] for i in range(exp.m) for j in range(exp.n)]


def run(quick: bool = True):
    """Reproduction note: coded schemes beat uncoded only when the straggler
    slowdown exceeds the coded scheme's effective degree (~3-5 for the
    sparse code at mn=16).  The paper's background-load stragglers are
    severe (uncoded/sparse ~ 3x in Table III); we report a moderate (5x)
    and a severe (10x) regime."""
    rows = []
    datasets = [("square", BENCH_SQUARE), ("tall", BENCH_TALL), ("fat", BENCH_FAT)]
    trials = 3 if quick else 20
    slowdowns = (5.0, 10.0)
    for dname, exp in [d for d in datasets]:
        rng = np.random.default_rng(7)
        blocks = _make_blocks(exp, rng)
        m, n, N = exp.m, exp.n, exp.num_workers + 12
        for slow in slowdowns:
            _bench_one(rows, f"{dname}/slow{slow:g}x", blocks, m, n, N,
                       SlowWorkers(num_slow=exp.num_stragglers, slowdown=slow),
                       trials)
    rows.extend(_chunked_sweep(quick))
    return rows


def _chunked_sweep(quick: bool = True):
    """Chunked vs atomic completion time at equal total work (acceptance:
    q >= 2 strictly below q = 1 under SlowWorkers).  Persisted under the
    ``completion_chunked`` key of BENCH_coded_matmul.json."""
    m = n = 4
    N, num_slow, slowdown = 24, 6, 10.0
    trials = 5 if quick else 25
    rng = np.random.default_rng(3)
    blocks = [rng.integers(-9, 10, size=(8, 8)).astype(np.float64)
              for _ in range(m * n)]
    strag = SlowWorkers(num_slow=num_slow, slowdown=slowdown)
    code = schemes.sparse_code(m, n, N, seed=1)
    sweep = {"m": m, "n": n, "num_workers": N, "num_slow": num_slow,
             "slowdown": slowdown, "trials": trials, "q": {}}
    rows = []
    for q in (1, 2, 4, 8):
        totals, chunks_used = [], []
        for t in range(trials):
            rep = run_coded_job(code, blocks, strag,
                                rng=np.random.default_rng(100 + t),
                                unit_block_time=0.05, num_chunks=q)
            totals.append(rep.sim_compute_time)
            chunks_used.append(rep.chunks_used)
        mean_t = float(np.mean(totals))
        sweep["q"][str(q)] = {"sim_compute_time": mean_t,
                              "mean_chunks_used": float(np.mean(chunks_used))}
        base = sweep["q"]["1"]["sim_compute_time"]
        rows.append(Row(
            f"completion_chunked/sparse_code_q{q}", mean_t * 1e6,
            f"sim={mean_t:.4f}s vs_atomic={base / max(mean_t, 1e-12):.2f}x "
            f"chunks={np.mean(chunks_used):.1f}"))
    qs = sweep["q"]
    sweep["chunked_strictly_faster"] = bool(
        all(qs[str(q)]["sim_compute_time"] < qs["1"]["sim_compute_time"]
            for q in (2, 4, 8)))
    merge_into_bench_json({"completion_chunked": sweep})
    rows.append(Row(
        "completion_chunked/strictly_faster", 0.0,
        str(sweep["chunked_strictly_faster"])))
    return rows


def _bench_one(rows, dname, blocks, m, n, N, strag, trials):
        for sname in SCHEME_ORDER:
            ctor = CTORS[sname]
            totals, decodes, waited, failed = [], [], [], 0
            for t in range(trials):
                code = ctor(m, n) if sname == "uncoded" else ctor(m, n, N, seed=t)
                try:
                    rep = run_coded_job(code, blocks, strag,
                                        rng=np.random.default_rng(100 + t),
                                        unit_block_time=0.05)
                except DecodingError:
                    failed += 1  # LT peeling can stall even with all workers
                    continue
                totals.append(rep.total_time)
                decodes.append(rep.decode_wall_time)
                waited.append(rep.workers_used)
            if not totals:
                rows.append(Row(f"tableIII/{dname}/{sname}", 0.0,
                                f"UNDECODABLE in {failed}/{trials} trials"))
                continue
            note = f" failed={failed}/{trials}" if failed else ""
            rows.append(Row(
                f"tableIII/{dname}/{sname}", float(np.mean(totals)) * 1e6,
                f"total={np.mean(totals):.4f}s decode={np.mean(decodes):.4f}s "
                f"workers={np.mean(waited):.1f}/"
                f"{N if sname != 'uncoded' else m*n}{note}"))
