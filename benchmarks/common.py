"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import scipy.sparse as sp

BENCH_JSON = pathlib.Path(__file__).parents[1] / "BENCH_coded_matmul.json"


def merge_into_bench_json(update: dict) -> None:
    """Read-modify-write BENCH_coded_matmul.json.

    Multiple suites persist into the one artifact CI uploads (the SPMD
    sweep, the chunked completion sweep), so every writer merges its
    top-level keys instead of clobbering the file.
    """
    data = {}
    if BENCH_JSON.exists():
        try:
            data = json.loads(BENCH_JSON.read_text())
        except json.JSONDecodeError:
            data = {}
    data.update(update)
    BENCH_JSON.write_text(json.dumps(data, indent=2) + "\n")


def sparse_bernoulli(rng, rows, cols, nnz):
    """Random sparse matrix with ~nnz nonzero +-1/values entries (the paper's
    random Bernoulli construction, dimension-scaled)."""
    density = min(1.0, nnz / (rows * cols))
    return sp.random(rows, cols, density=density, format="csc",
                     random_state=np.random.RandomState(rng.integers(2**31)),
                     data_rvs=lambda n: rng.integers(1, 5, n).astype(np.float64))


def timeit(fn, repeats=3, warmup=1):
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class Row:
    """One CSV row: name, us_per_call, derived."""

    def __init__(self, name: str, us: float, derived: str = ""):
        self.name = name
        self.us = us
        self.derived = derived

    def __str__(self):
        return f"{self.name},{self.us:.1f},{self.derived}"
