"""Kernel-lane roofline benchmark: fused decode epilogue vs two launches.

Benchmarks the coded local product at the KERNEL level (no mesh, no psum):
``spmm_block_fused_decode`` (one launch, decode combine in the epilogue)
against the historical two-step path (local product launch, then the
decode broadcast-multiply as a second launch), on whatever lane
``resolve_lane`` picks for this host -- XLA on CPU CI, Pallas-Triton on
GPU, compiled Pallas on TPU.  Results are reported as FRACTION of this
machine's calibrated roofline (``repro.launch.roofline.machine_peaks``),
not just wall-clock, so a number from the CPU CI box and a number from a
GPU runner mean the same thing.  Quantized packs (bf16 / int8 tile values,
weights exact) ride along as a dtype sweep of the fused kernel.

Persists the ``kernel`` key of BENCH_coded_matmul.json.
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

from benchmarks.common import Row, merge_into_bench_json

_SCRIPT = r"""
import os
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp

jax.devices()  # pin the backend BEFORE roofline's XLA_FLAGS import hook
from repro.launch.roofline import machine_peaks, fused_kernel_cost, roofline_fraction
from repro.kernels import ops
from repro.kernels.spmm_block import resolve_lane

FULL = bool(int(sys.argv[1])) if len(sys.argv) > 1 else False

CB, L, bs, mn = (64, 32, 8, 4) if FULL else (32, 32, 8, 4)
bt = 256 if FULL else 128
s, t = 64 * bs, 2 * bt
br = CB * bs

rng = np.random.default_rng(0)
vals32 = rng.normal(size=(CB, L, bs, bs)).astype(np.float32)
src = np.stack([rng.integers(0, s // bs, (CB, L)),
                rng.integers(0, t // bt, (CB, L))], -1).astype(np.int32)
wslot = rng.normal(size=(CB, L)).astype(np.float32)
dvec = rng.normal(size=(mn,)).astype(np.float32)
B = jnp.asarray(rng.normal(size=(s, t)), jnp.float32)
src_j = jnp.asarray(src); w_j = jnp.asarray(wslot); d_j = jnp.asarray(dvec)

def bench(fn, *args, reps=20):
    fn(*args).block_until_ready()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))

lane = resolve_lane()
peaks = machine_peaks()

# two launches: the local product, then the decode combine as its own jit
# (a launch boundary, exactly what the staged program used to pay)
step1 = jax.jit(lambda v, s_, w, b: ops.spmm_block_fused(v, s_, w, b, bt=bt))
step2 = jax.jit(lambda d, c: d[:, None, None] * c[None])
def two_step(v, s_, w, d, b):
    return step2(d, step1(v, s_, w, b))
fused = jax.jit(lambda v, s_, w, d, b:
                ops.spmm_block_fused_decode(v, s_, w, d, b, bt=bt))

out = {"lane": lane, "peaks": peaks,
       "shape": {"CB": CB, "L": L, "bs": bs, "bt": bt, "mn": mn,
                 "s": s, "t": t}}

v32 = jnp.asarray(vals32)
ref = np.asarray(two_step(v32, src_j, w_j, d_j, B))
got = np.asarray(fused(v32, src_j, w_j, d_j, B))
out["max_err_fused_vs_two_step"] = float(np.abs(got - ref).max())

t_unfused = bench(two_step, v32, src_j, w_j, d_j, B)
t_fused = bench(fused, v32, src_j, w_j, d_j, B)
live = CB * L
cost = fused_kernel_cost(live_tiles=live, bs=bs, bt=bt, mn=mn, br=br,
                         fused=True)
out["t_unfused_s"] = t_unfused
out["t_fused_s"] = t_fused
out["roofline_fraction_fused"] = roofline_fraction(cost, t_fused, peaks)
out["roofline_fraction_unfused"] = roofline_fraction(cost, t_unfused, peaks)
out["fused_ge_unfused"] = bool(
    out["roofline_fraction_fused"] >= out["roofline_fraction_unfused"])
out["speedup_fused"] = t_unfused / max(t_fused, 1e-12)

# quantized tile sweep: same kernel, tiles stored bf16 / int8 (weights
# exact; int8 scale folded into the weights, as the pack layer does)
out["dtypes"] = {}
for name, itemsize in (("float32", 4), ("bfloat16", 2), ("int8", 1)):
    if name == "float32":
        v, w_eff = v32, w_j
    elif name == "bfloat16":
        v, w_eff = v32.astype(jnp.bfloat16), w_j
    else:
        amax = np.abs(vals32).max(axis=(-2, -1))
        scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
        v = jnp.asarray(np.rint(vals32 / scale[..., None, None]).astype(np.int8))
        # per-tile scale folds into the per-slot weight (CB, L)
        w_eff = w_j * jnp.asarray(scale)
    tq = bench(fused, v, src_j, w_eff, d_j, B)
    cq = fused_kernel_cost(live_tiles=live, bs=bs, bt=bt, mn=mn, br=br,
                           fused=True, tile_itemsize=itemsize)
    errq = float(np.abs(np.asarray(fused(v, src_j, w_eff, d_j, B)) - ref).max())
    out["dtypes"][name] = {
        "t_s": tq, "max_err": errq,
        "roofline_fraction": roofline_fraction(cq, tq, peaks)}

print(json.dumps(out))
"""


def run(quick: bool = True):
    root = pathlib.Path(__file__).parents[1]
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, "0" if quick else "1"],
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        capture_output=True, text=True, timeout=900)
    rows = []
    if proc.returncode != 0:
        rows.append(Row("kernel/ERROR", 0.0, proc.stderr[-200:]))
        return rows
    d = json.loads(proc.stdout.strip().splitlines()[-1])
    merge_into_bench_json({"kernel": d})
    rows.append(Row(
        f"kernel/fused_decode_{d['lane']}", d["t_fused_s"] * 1e6,
        f"roofline={d['roofline_fraction_fused']:.3f} "
        f"err={d['max_err_fused_vs_two_step']:.2e}"))
    rows.append(Row(
        f"kernel/two_step_{d['lane']}", d["t_unfused_s"] * 1e6,
        f"roofline={d['roofline_fraction_unfused']:.3f} "
        f"fused_speedup={d['speedup_fused']:.2f}x "
        f"fused_ge_unfused={d['fused_ge_unfused']}"))
    for name, dd in d["dtypes"].items():
        rows.append(Row(
            f"kernel/fused_{name}", dd["t_s"] * 1e6,
            f"roofline={dd['roofline_fraction']:.3f} err={dd['max_err']:.2e}"))
    return rows
