"""Chaos recovery time: process runtime (real faults) vs simulator prediction.

For each fault class the chaos language speaks (kill mid-chunk, pause past
the heartbeat deadline, slow 10x, drop_result), run the SAME fault
realization twice:

* measured -- ``run_proc_job`` injects the fault into real spawn-started
  subprocess workers and the master recovers from the surviving chunk
  prefixes; we report its compute (recovery) wall time.
* predicted -- ``run_coded_job`` under ``FaultRealization(plan)``, the
  simulator twin that edits the (N, q) chunk timeline the way the injector
  edits reality (stretch / cut / shift), with ``unit_block_time`` calibrated
  from an UNFAULTED process-runtime baseline so the two clocks agree on what
  a healthy job costs.

Persisted under the ``chaos`` key of BENCH_coded_matmul.json (merged, never
clobbering other suites' keys): per class the measured and predicted recovery
seconds, their ratio, and the fault ledger kinds the run produced -- CI
tracks that real recovery stays within sight of the model.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, merge_into_bench_json, sparse_bernoulli
from repro.core import schemes
from repro.core.encoder import compute_block_products, split_blocks
from repro.runtime import NoStragglers, run_coded_job
from repro.runtime.chaos import (
    FaultPlan,
    FaultRealization,
    drop_result,
    kill,
    pause,
    slow,
)
from repro.runtime.procpool import run_proc_job

M_SPLIT = N_SPLIT = 2
NUM_WORKERS = 8
NUM_CHUNKS = 4
SLEEP = 0.4          # injected per-worker sleep, spread across chunks
HB_DEADLINE = 1.0

FAULT_CLASSES = [
    ("kill", lambda: [kill(1, after_chunk=0)]),
    ("pause_past_deadline", lambda: [pause(2, after_chunk=0)]),
    ("slow10x", lambda: [slow(3, factor=10.0)]),
    ("drop_result", lambda: [drop_result(1, chunk=1)]),
]


def _job_inputs(rng):
    A = sparse_bernoulli(rng, 60, 24, 500)
    B = sparse_bernoulli(rng, 60, 20, 400)
    A_blocks = split_blocks(A, M_SPLIT)
    B_blocks = split_blocks(B, N_SPLIT)
    prods = compute_block_products(A_blocks, B_blocks)
    blocks_true = [prods[i][j] for i in range(M_SPLIT) for j in range(N_SPLIT)]
    return A_blocks, B_blocks, blocks_true


def _proc(code, A_blocks, B_blocks, plan):
    rep = run_proc_job(
        code, A_blocks, B_blocks, N_SPLIT, num_chunks=NUM_CHUNKS,
        straggler_sleep={w: SLEEP for w in range(NUM_WORKERS)},
        plan=plan, timeout=60.0,
        heartbeat_interval=0.05, heartbeat_deadline=HB_DEADLINE)
    return rep


def run(quick: bool = True):
    trials = 1 if quick else 3
    rng = np.random.default_rng(13)
    A_blocks, B_blocks, blocks_true = _job_inputs(rng)
    code = schemes.sparse_code(M_SPLIT, N_SPLIT, NUM_WORKERS, seed=4)

    # ---- calibrate the simulator clock against an unfaulted process run ----
    baseline = [_proc(code, A_blocks, B_blocks, None) for _ in range(trials)]
    measured_base = float(np.mean([r.sim_compute_time for r in baseline]))
    sim_base = run_coded_job(code, blocks_true, NoStragglers(),
                             rng=np.random.default_rng(0),
                             unit_block_time=1.0,
                             num_chunks=NUM_CHUNKS).sim_compute_time
    unit = measured_base / max(float(sim_base), 1e-9)

    results = {
        "num_workers": NUM_WORKERS, "num_chunks": NUM_CHUNKS,
        "straggler_sleep": SLEEP, "heartbeat_deadline": HB_DEADLINE,
        "trials": trials,
        "baseline_proc_seconds": measured_base,
        "calibrated_unit_block_time": unit,
        "classes": {},
    }
    rows = [Row("chaos/baseline_proc", measured_base * 1e6,
                f"unfaulted proc run, unit={unit:.4f}s/block")]

    for name, plan_for in FAULT_CLASSES:
        plan = FaultPlan.coerce(plan_for())
        measured, kinds = [], []
        for _ in range(trials):
            rep = _proc(code, A_blocks, B_blocks, plan)
            measured.append(rep.sim_compute_time)
            kinds = sorted({e["kind"] for e in rep.fault_ledger})
        measured_s = float(np.mean(measured))
        predicted_s = float(run_coded_job(
            code, blocks_true, FaultRealization(plan=plan),
            rng=np.random.default_rng(0), unit_block_time=unit,
            num_chunks=NUM_CHUNKS).sim_compute_time)
        ratio = measured_s / max(predicted_s, 1e-9)
        results["classes"][name] = {
            "measured_recovery_seconds": measured_s,
            "predicted_recovery_seconds": predicted_s,
            "measured_over_predicted": ratio,
            "ledger_kinds": kinds,
        }
        rows.append(Row(
            f"chaos/{name}", measured_s * 1e6,
            f"measured={measured_s:.3f}s predicted={predicted_s:.3f}s "
            f"ratio={ratio:.2f} ledger={'+'.join(kinds)}"))

    merge_into_bench_json({"chaos": results})
    return rows
