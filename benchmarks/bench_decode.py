"""Paper Theorem 1: decode complexity O(nnz(C) ln(mn)) -- linear in nnz,
independent of the rt dimension.

Two sweeps with the hybrid decoder on real sparse blocks:
  (a) fixed dimensions, growing nnz(C)      -> time grows ~linearly;
  (b) fixed nnz(C), growing dimensions r,t  -> time ~flat (the claim that
      kills the O(rt) decoders);
plus a head-to-head against Gaussian elimination (the dense decode every
O(rt)-class scheme pays).
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from benchmarks.common import Row, timeit
from repro.core import schemes
from repro.core.decoder import gaussian_decode, hybrid_decode


def _coded_results(code, blocks):
    M = code.M
    out = []
    for r in range(M.shape[0]):
        lo, hi = M.indptr[r], M.indptr[r + 1]
        acc = None
        for c, w in zip(M.indices[lo:hi], M.data[lo:hi]):
            term = blocks[c] * w
            acc = term if acc is None else acc + term
        out.append(acc if acc is not None else blocks[0] * 0.0)
    return out


def _sparse_blocks(rng, d, dim, nnz_per_block):
    # direct coo sampling: O(nnz), no dim*dim permutation (sp.random would
    # materialize one at these dimensions); index collisions just merge.
    out = []
    for _ in range(d):
        r = rng.integers(0, dim, nnz_per_block)
        c = rng.integers(0, dim, nnz_per_block)
        v = rng.standard_normal(nnz_per_block)
        out.append(sp.coo_matrix((v, (r, c)), shape=(dim, dim)).tocsr())
    return out


def run(quick: bool = True):
    rows = []
    m = n = 4
    d = m * n
    rng = np.random.default_rng(3)
    code = schemes.sparse_code(m, n, 3 * d, seed=1)

    # (a) growing nnz at fixed dims
    for nnz in ([2_000, 8_000, 32_000] if quick else [2_000, 8_000, 32_000, 128_000]):
        blocks = _sparse_blocks(rng, d, 1500, nnz)
        results = _coded_results(code, blocks)
        t = timeit(lambda: hybrid_decode(code.M, list(results)), repeats=3)
        rows.append(Row(f"thm1/nnz_{nnz}", t * 1e6,
                        f"decode={t*1e3:.2f}ms nnz_total={nnz*d}"))

    # (b) growing dims at fixed nnz
    for dim in ([1000, 4000, 16000] if quick else [1000, 4000, 16000, 64000]):
        blocks = _sparse_blocks(rng, d, dim, 8000)
        results = _coded_results(code, blocks)
        t = timeit(lambda: hybrid_decode(code.M, list(results)), repeats=3)
        rows.append(Row(f"thm1/dim_{dim}", t * 1e6,
                        f"decode={t*1e3:.2f}ms rt={dim*dim*d} (time ~flat)"))

    # hybrid vs gaussian on the same instance
    blocks = _sparse_blocks(rng, d, 2000, 8000)
    results = _coded_results(code, blocks)
    th = timeit(lambda: hybrid_decode(code.M, list(results)), repeats=3)
    tg = timeit(lambda: gaussian_decode(code.M, list(results)), repeats=3)
    rows.append(Row("thm1/hybrid_vs_gaussian", th * 1e6,
                    f"hybrid={th*1e3:.2f}ms gaussian={tg*1e3:.2f}ms "
                    f"speedup={tg/max(th,1e-9):.1f}x"))
    return rows
