"""SPMD integration benchmark (no paper figure -- the framework's own table):
coded vs uncoded distributed matmul on a JAX mesh, across both local-compute
backends (dense_scan vs the block-sparse Pallas path).

Runs in a subprocess with 8 host devices (this process keeps the default
single device).  Reports wall time, the redundancy overhead of the coded
path, the dense-vs-block-sparse backend ratio on a block-sparse operand,
plus the fault-tolerance outcome (decode with a killed worker)."""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

from benchmarks.common import Row

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro import compat
from repro.core.coded_matmul import coded_matmul, make_plan, uncoded_matmul_reference
from repro.sparse import dense_to_block_ell

mesh = compat.make_mesh((8,), ("model",),
                        axis_types=compat.auto_axis_types(1))
m = n = 2
plan = make_plan(m, n, num_workers=8, seed=0)
# sized for CPU-interpret Pallas (the block_sparse backend timing here is the
# interpreter's, not the MXU's -- the comparison is structural, not absolute)
s, r, t = 512, 256, 256
bs = 8
rng = np.random.default_rng(0)
# block-sparse A (~10% of 8x8 tiles live): the regime where the block_sparse
# backend's nnz-proportional local compute should pay off
mask = rng.random((s // bs, r // bs)) < 0.10
A_np = rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
A = jnp.asarray(A_np, jnp.float32)
B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)

# the tile pack is static metadata: build it on host, outside jit
ell = dense_to_block_ell(np.asarray(A_np, np.float32), block_size=bs)
coded = {
    "dense_scan": jax.jit(lambda a, b: coded_matmul(
        a, b, plan, mesh, backend="dense_scan")),
    "block_sparse": jax.jit(lambda a, b: coded_matmul(
        a, b, plan, mesh, backend="block_sparse", a_sparse=ell)),
}
unc = jax.jit(uncoded_matmul_reference)

def bench(fn, *args):
    fn(*args).block_until_ready()
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))

out = {"max_degree": plan.max_degree,
       "block_density": float(mask.mean())}
ref = unc(A, B)
for backend, fn in coded.items():
    out[f"t_{backend}"] = bench(fn, A, B)
    out[f"err_{backend}"] = float(jnp.max(jnp.abs(fn(A, B) - ref)))
out["t_uncoded"] = bench(unc, A, B)

# fault tolerance: kill worker 3, decode from survivors on both backends
surv = np.ones(8, dtype=bool); surv[3] = False
for backend in coded:
    kw = {"a_sparse": ell} if backend == "block_sparse" else {}
    try:
        C2 = coded_matmul(A, B, plan, mesh, survivors=surv, backend=backend, **kw)
        out[f"ft_err_{backend}"] = float(jnp.max(jnp.abs(C2 - ref)))
    except ValueError:   # DecodingError is a ValueError: rank lost
        out[f"ft_err_{backend}"] = float("nan")

print(json.dumps(out))
"""


def run(quick: bool = True):
    src = pathlib.Path(__file__).parents[1] / "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT],
                          env={"PYTHONPATH": str(src), "PATH": "/usr/bin:/bin",
                               "HOME": "/root"},
                          capture_output=True, text=True, timeout=900)
    rows = []
    if proc.returncode != 0:
        rows.append(Row("coded_matmul/ERROR", 0.0, proc.stderr[-200:]))
        return rows
    d = json.loads(proc.stdout.strip().splitlines()[-1])
    t_dense = d["t_dense_scan"]
    t_block = d["t_block_sparse"]
    rows.append(Row("coded_matmul/coded_dense_scan_8dev", t_dense * 1e6,
                    f"max_err={d['err_dense_scan']:.2e} max_degree={d['max_degree']}"))
    rows.append(Row(
        "coded_matmul/coded_block_sparse_8dev", t_block * 1e6,
        f"max_err={d['err_block_sparse']:.2e} "
        f"block_density={d['block_density']:.2f} "
        f"vs_dense={t_dense / max(t_block, 1e-12):.2f}x"))
    rows.append(Row("coded_matmul/uncoded_8dev", d["t_uncoded"] * 1e6,
                    f"overhead={t_dense / max(d['t_uncoded'], 1e-12):.2f}x"))
    rows.append(Row(
        "coded_matmul/fault_tolerant_decode", 0.0,
        f"killed_worker_3_err dense={d['ft_err_dense_scan']:.2e} "
        f"block_sparse={d['ft_err_block_sparse']:.2e}"))
    return rows
