"""SPMD integration benchmark (no paper figure -- the framework's own table):
coded vs uncoded distributed matmul on a JAX mesh, across both local-compute
backends (dense_scan vs the fused-gather block-sparse path), swept over
block densities {2%, 10%, 30%}.

Runs in a subprocess with 8 host devices (this process keeps the default
single-device platform).  Reports wall time per (density, backend), the
scatter-decode variant, the redundancy overhead of the coded path, and the
fault-tolerance outcome (decode with a killed worker).  The full result
dict is persisted to BENCH_coded_matmul.json at the repo root, seeding the
perf trajectory the CI artifact tracks."""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

from benchmarks.common import Row

DENSITIES = (0.02, 0.10, 0.30)

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp
from repro import compat
from repro.core.coded_matmul import coded_matmul, make_plan, uncoded_matmul_reference
from repro.sparse import dense_to_block_ell

FULL = bool(int(sys.argv[1])) if len(sys.argv) > 1 else False
DENSITIES = json.loads(sys.argv[2]) if len(sys.argv) > 2 else [0.02, 0.10, 0.30]

mesh = compat.make_mesh((8,), ("model",),
                        axis_types=compat.auto_axis_types(1))
m = n = 2
plan = make_plan(m, n, num_workers=8, seed=0)
s, r, t = (1024, 512, 512) if FULL else (512, 256, 256)
bs = 8
rng = np.random.default_rng(0)
B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
unc = jax.jit(uncoded_matmul_reference)

def bench(fn, *args):
    fn(*args).block_until_ready()
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))

out = {"max_degree": plan.max_degree, "shape": {"s": s, "r": r, "t": t},
       "block_size": bs, "num_workers": 8, "densities": {}}

for density in DENSITIES:
    mask = rng.random((s // bs, r // bs)) < density
    A_np = rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
    A = jnp.asarray(A_np, jnp.float32)
    # the tile pack is static metadata: build it on host, outside jit
    ell = dense_to_block_ell(np.asarray(A_np, np.float32), block_size=bs)
    fns = {
        "dense_scan": jax.jit(lambda a, b: coded_matmul(
            a, b, plan, mesh, backend="dense_scan")),
        "block_sparse": jax.jit(lambda a, b: coded_matmul(
            a, b, plan, mesh, backend="block_sparse", a_sparse=ell)),
        "block_sparse_scatter": jax.jit(lambda a, b: coded_matmul(
            a, b, plan, mesh, backend="block_sparse", a_sparse=ell,
            out_sharded=True)),
    }
    ref = unc(A, B)
    d = {"block_density": float(mask.mean()),
         "live_tile_fraction": float(ell.nnzb.sum()) / ((s // bs) * (r // bs))}
    for name, fn in fns.items():
        d[f"t_{name}"] = bench(fn, A, B)
        d[f"err_{name}"] = float(jnp.max(jnp.abs(fn(A, B) - ref)))
    d["t_uncoded"] = bench(unc, A, B)
    d["speedup_block_vs_dense"] = d["t_dense_scan"] / max(d["t_block_sparse"], 1e-12)
    out["densities"][f"{density:.2f}"] = d

# fault tolerance at the middle density: kill worker 3, decode from survivors
density = DENSITIES[len(DENSITIES) // 2]
mask = rng.random((s // bs, r // bs)) < density
A_np = rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
A = jnp.asarray(A_np, jnp.float32)
ell = dense_to_block_ell(np.asarray(A_np, np.float32), block_size=bs)
ref = unc(A, B)
surv = np.ones(8, dtype=bool); surv[3] = False
for backend in ("dense_scan", "block_sparse"):
    kw = {"a_sparse": ell} if backend == "block_sparse" else {}
    try:
        C2 = coded_matmul(A, B, plan, mesh, survivors=surv, backend=backend, **kw)
        out[f"ft_err_{backend}"] = float(jnp.max(jnp.abs(C2 - ref)))
    except ValueError:   # DecodingError is a ValueError: rank lost
        out[f"ft_err_{backend}"] = float("nan")

print(json.dumps(out))
"""


def run(quick: bool = True):
    root = pathlib.Path(__file__).parents[1]
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, "0" if quick else "1",
         json.dumps(list(DENSITIES))],
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    rows = []
    if proc.returncode != 0:
        rows.append(Row("coded_matmul/ERROR", 0.0, proc.stderr[-200:]))
        return rows
    d = json.loads(proc.stdout.strip().splitlines()[-1])
    (root / "BENCH_coded_matmul.json").write_text(json.dumps(d, indent=2) + "\n")
    for key, dd in d["densities"].items():
        rows.append(Row(
            f"coded_matmul/dense_scan_8dev_d{key}", dd["t_dense_scan"] * 1e6,
            f"max_err={dd['err_dense_scan']:.2e} max_degree={d['max_degree']}"))
        rows.append(Row(
            f"coded_matmul/block_sparse_8dev_d{key}", dd["t_block_sparse"] * 1e6,
            f"max_err={dd['err_block_sparse']:.2e} "
            f"vs_dense={dd['speedup_block_vs_dense']:.2f}x"))
        rows.append(Row(
            f"coded_matmul/block_sparse_scatter_8dev_d{key}",
            dd["t_block_sparse_scatter"] * 1e6,
            f"max_err={dd['err_block_sparse_scatter']:.2e}"))
        rows.append(Row(
            f"coded_matmul/uncoded_8dev_d{key}", dd["t_uncoded"] * 1e6,
            f"overhead={dd['t_dense_scan'] / max(dd['t_uncoded'], 1e-12):.2f}x"))
    rows.append(Row(
        "coded_matmul/fault_tolerant_decode", 0.0,
        f"killed_worker_3_err dense={d['ft_err_dense_scan']:.2e} "
        f"block_sparse={d['ft_err_block_sparse']:.2e}"))
    return rows
