"""SPMD integration benchmark (no paper figure -- the framework's own table):
coded vs uncoded distributed matmul on a JAX mesh, across both local-compute
backends (dense_scan vs the fused-gather block-sparse path), swept over
block densities {2%, 10%, 30%}.  Driven through the ``repro.coded`` op API
(one bound ``CodedOp`` per backend x decode layout; straggler decode via
``with_survivors``).

Runs in a subprocess with 8 host devices (this process keeps the default
single-device platform).  Reports wall time per (density, backend), the
scatter-decode variant, the redundancy overhead of the coded path, and the
fault-tolerance outcome (decode with a killed worker).  The full result
dict is persisted to BENCH_coded_matmul.json at the repo root, seeding the
perf trajectory the CI artifact tracks."""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

from benchmarks.common import Row, merge_into_bench_json

DENSITIES = (0.02, 0.10, 0.30)

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp
from repro import compat
from repro.coded import CodedMatmulConfig, from_plan
from repro.core.coded_matmul import make_plan, uncoded_matmul_reference
from repro.sparse import dense_to_block_ell

FULL = bool(int(sys.argv[1])) if len(sys.argv) > 1 else False
DENSITIES = json.loads(sys.argv[2]) if len(sys.argv) > 2 else [0.02, 0.10, 0.30]

mesh = compat.make_mesh((8,), ("model",),
                        axis_types=compat.auto_axis_types(1))
m = n = 2
plan = make_plan(m, n, num_workers=8, seed=0)
s, r, t = (1024, 512, 512) if FULL else (512, 256, 256)
bs = 8
rng = np.random.default_rng(0)
B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
unc = jax.jit(uncoded_matmul_reference)

# one bound CodedOp per (backend x decode layout); packs resolve through the
# op (and its pack cache) per operand below
OPS = {
    "dense_scan": from_plan(CodedMatmulConfig(
        backend="dense_scan"), plan).bind(mesh),
    "block_sparse": from_plan(CodedMatmulConfig(
        backend="block_sparse", block_size=bs), plan).bind(mesh),
    "block_sparse_scatter": from_plan(CodedMatmulConfig(
        backend="block_sparse", block_size=bs, out_sharded=True),
        plan).bind(mesh),
}

def bench(fn, *args):
    fn(*args).block_until_ready()
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))

out = {"max_degree": plan.max_degree, "shape": {"s": s, "r": r, "t": t},
       "block_size": bs, "num_workers": 8, "densities": {}}

for density in DENSITIES:
    mask = rng.random((s // bs, r // bs)) < density
    A_np = rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
    A = jnp.asarray(A_np, jnp.float32)
    # the tile pack is static metadata: build it on host, outside jit
    ell = dense_to_block_ell(np.asarray(A_np, np.float32), block_size=bs)
    fns = {}
    for name, op in OPS.items():
        kw = {"a_sparse": ell} if op.needs_pack else {}
        fns[name] = jax.jit(lambda a, b, op=op, kw=kw: op.apply(a, b, **kw))
    ref = unc(A, B)
    d = {"block_density": float(mask.mean()),
         "live_tile_fraction": float(ell.nnzb.sum()) / ((s // bs) * (r // bs))}
    for name, fn in fns.items():
        d[f"t_{name}"] = bench(fn, A, B)
        d[f"err_{name}"] = float(jnp.max(jnp.abs(fn(A, B) - ref)))
    d["t_uncoded"] = bench(unc, A, B)
    d["speedup_block_vs_dense"] = d["t_dense_scan"] / max(d["t_block_sparse"], 1e-12)
    out["densities"][f"{density:.2f}"] = d

# fault tolerance at the middle density: kill worker 3, rebind the op to the
# survivors (the pack is reused -- it depends only on the task table)
density = DENSITIES[len(DENSITIES) // 2]
mask = rng.random((s // bs, r // bs)) < density
A_np = rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
A = jnp.asarray(A_np, jnp.float32)
ell = dense_to_block_ell(np.asarray(A_np, np.float32), block_size=bs)
ref = unc(A, B)
surv = np.ones(8, dtype=bool); surv[3] = False
for backend in ("dense_scan", "block_sparse"):
    kw = {"a_sparse": ell} if OPS[backend].needs_pack else {}
    try:
        # with_survivors raises DecodingError (a ValueError) EAGERLY on
        # rank loss, so the rebind must sit inside the recording try
        C2 = OPS[backend].with_survivors(surv).apply(A, B, **kw)
        out[f"ft_err_{backend}"] = float(jnp.max(jnp.abs(C2 - ref)))
    except ValueError:   # rank lost: record the outcome, don't crash the bench
        out[f"ft_err_{backend}"] = float("nan")

print(json.dumps(out))
"""


def run(quick: bool = True):
    root = pathlib.Path(__file__).parents[1]
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, "0" if quick else "1",
         json.dumps(list(DENSITIES))],
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        capture_output=True, text=True, timeout=900)
    rows = []
    if proc.returncode != 0:
        rows.append(Row("coded_matmul/ERROR", 0.0, proc.stderr[-200:]))
        return rows
    d = json.loads(proc.stdout.strip().splitlines()[-1])
    # merge: the completion suite persists its chunked sweep into the same
    # artifact, so preserve keys this suite does not own
    merge_into_bench_json(d)
    for key, dd in d["densities"].items():
        rows.append(Row(
            f"coded_matmul/dense_scan_8dev_d{key}", dd["t_dense_scan"] * 1e6,
            f"max_err={dd['err_dense_scan']:.2e} max_degree={d['max_degree']}"))
        rows.append(Row(
            f"coded_matmul/block_sparse_8dev_d{key}", dd["t_block_sparse"] * 1e6,
            f"max_err={dd['err_block_sparse']:.2e} "
            f"vs_dense={dd['speedup_block_vs_dense']:.2f}x"))
        rows.append(Row(
            f"coded_matmul/block_sparse_scatter_8dev_d{key}",
            dd["t_block_sparse_scatter"] * 1e6,
            f"max_err={dd['err_block_sparse_scatter']:.2e}"))
        rows.append(Row(
            f"coded_matmul/uncoded_8dev_d{key}", dd["t_uncoded"] * 1e6,
            f"overhead={dd['t_dense_scan'] / max(dd['t_uncoded'], 1e-12):.2f}x"))
    rows.append(Row(
        "coded_matmul/fault_tolerant_decode", 0.0,
        f"killed_worker_3_err dense={d['ft_err_dense_scan']:.2e} "
        f"block_sparse={d['ft_err_block_sparse']:.2e}"))
    return rows
