"""SPMD integration benchmark (no paper figure -- the framework's own table):
coded vs uncoded distributed matmul on a JAX mesh.

Runs in a subprocess with 8 host devices (this process keeps the default
single device).  Reports wall time and the redundancy overhead of the coded
path, plus the fault-tolerance outcome (decode with a killed worker).
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

from benchmarks.common import Row

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
import json, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.coded_matmul import coded_matmul, make_plan, uncoded_matmul_reference

mesh = jax.make_mesh((8,), ("model",),
                     axis_types=(jax.sharding.AxisType.Auto,))
m = n = 2
plan = make_plan(m, n, num_workers=8, seed=0)
s, r, t = 1024, 512, 512
rng = np.random.default_rng(0)
A = jnp.asarray(rng.standard_normal((s, r)), jnp.float32)
B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)

coded = jax.jit(lambda a, b: coded_matmul(a, b, plan, mesh))
unc = jax.jit(uncoded_matmul_reference)

def bench(fn, *args):
    fn(*args).block_until_ready()
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        fn(*args).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))

t_cod = bench(coded, A, B)
t_unc = bench(unc, A, B)
err = float(jnp.max(jnp.abs(coded(A, B) - unc(A, B))))

# fault tolerance: kill worker 3
surv = np.ones(8, dtype=bool); surv[3] = False
try:
    C2 = coded_matmul(A, B, plan, mesh, survivors=surv)
    ft_err = float(jnp.max(jnp.abs(C2 - unc(A, B))))
except ValueError:
    ft_err = float("nan")

print(json.dumps({"t_coded": t_cod, "t_uncoded": t_unc, "max_err": err,
                  "ft_err": ft_err, "max_degree": plan.max_degree}))
"""


def run(quick: bool = True):
    src = pathlib.Path(__file__).parents[1] / "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT],
                          env={"PYTHONPATH": str(src), "PATH": "/usr/bin:/bin",
                               "HOME": "/root"},
                          capture_output=True, text=True, timeout=600)
    rows = []
    if proc.returncode != 0:
        rows.append(Row("coded_matmul/ERROR", 0.0, proc.stderr[-200:]))
        return rows
    d = json.loads(proc.stdout.strip().splitlines()[-1])
    rows.append(Row("coded_matmul/coded_8dev", d["t_coded"] * 1e6,
                    f"max_err={d['max_err']:.2e} max_degree={d['max_degree']}"))
    rows.append(Row("coded_matmul/uncoded_8dev", d["t_uncoded"] * 1e6,
                    f"overhead={d['t_coded']/max(d['t_uncoded'],1e-12):.2f}x"))
    rows.append(Row("coded_matmul/fault_tolerant_decode", 0.0,
                    f"killed_worker_3_err={d['ft_err']:.2e}"))
    return rows
