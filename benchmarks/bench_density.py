"""Paper Fig. 1(b): ratio of coded (polynomial) to uncoded local computation
time versus input density p.

The polynomial code's worker multiplies m- and n-fold densified inputs; the
uncoded worker multiplies one raw block pair.  The paper observes a ~O(mn)
ratio in the sparse regime, decaying as p grows.
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from benchmarks.common import Row, sparse_bernoulli, timeit


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    m = n = 3
    size = 3000 if quick else 20_000
    rows = []
    for p in ([1e-4, 5e-4, 2e-3, 1e-2] if quick else [1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2]):
        A = sp.random(size, size, density=p, format="csc",
                      random_state=np.random.RandomState(0))
        B = sp.random(size, size, density=p, format="csc",
                      random_state=np.random.RandomState(1))
        bs = size // m
        A_blocks = [A[:, i*bs:(i+1)*bs] for i in range(m)]
        B_blocks = [B[:, j*bs:(j+1)*bs] for j in range(n)]
        # uncoded: one block product
        t_unc = timeit(lambda: A_blocks[0].T @ B_blocks[0])
        # polynomial-coded: densified combinations, one product
        x = 0.73
        At = sum(Ai * (x ** i) for i, Ai in enumerate(A_blocks))
        Bt = sum(Bj * (x ** (j * m)) for j, Bj in enumerate(B_blocks))
        t_cod = timeit(lambda: At.T @ Bt)
        ratio = t_cod / max(t_unc, 1e-9)
        rows.append(Row(f"fig1b/density_{p:g}", t_cod * 1e6,
                        f"ratio_coded_over_uncoded={ratio:.2f} (mn={m*n})"))
    return rows
