"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--quick | --full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows.  --full uses the larger
configurations (slower, closer to the paper's dimensions); --quick is the
default small configuration, spelled out for CI invocations.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_chaos,
    bench_completion,
    bench_components,
    bench_coded_matmul,
    bench_decode,
    bench_density,
    bench_kernels,
    bench_recovery,
    bench_serving,
)

SUITES = {
    "density": bench_density,        # Fig 1(b)
    "recovery": bench_recovery,      # Fig 4 / Table IV
    "completion": bench_completion,  # Fig 5 / Table III
    "components": bench_components,  # Fig 6
    "decode": bench_decode,          # Theorem 1
    "coded_matmul": bench_coded_matmul,  # SPMD integration
    "kernel": bench_kernels,         # one-launch fused decode vs roofline
    "chaos": bench_chaos,            # process runtime vs simulator twin
    "serving": bench_serving,        # multi-tenant coded serving SLOs
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    size = ap.add_mutually_exclusive_group()
    size.add_argument("--full", action="store_true")
    size.add_argument("--quick", action="store_true",
                      help="small configurations (the default, made explicit)")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args(argv)

    names = [args.only] if args.only else list(SUITES)
    failed = []
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        try:
            rows = SUITES[name].run(quick=not args.full)
        except Exception as e:  # noqa: BLE001 -- keep the suite going
            print(f"{name}/SUITE_ERROR,0.0,{type(e).__name__}: {e}")
            failed.append(name)
            continue
        for row in rows:
            print(row)
            if "/ERROR" in str(row).split(",", 1)[0]:
                failed.append(name)
        print(f"# {name} finished in {time.time() - t0:.1f}s", file=sys.stderr)
    if failed:
        # exit nonzero so CI goes red on the bench step itself, not on a
        # downstream missing-artifact message
        print(f"# FAILED suites: {sorted(set(failed))}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
