"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows.  --full uses the larger
configurations (slower, closer to the paper's dimensions).
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    bench_completion,
    bench_components,
    bench_coded_matmul,
    bench_decode,
    bench_density,
    bench_recovery,
)

SUITES = {
    "density": bench_density,        # Fig 1(b)
    "recovery": bench_recovery,      # Fig 4 / Table IV
    "completion": bench_completion,  # Fig 5 / Table III
    "components": bench_components,  # Fig 6
    "decode": bench_decode,          # Theorem 1
    "coded_matmul": bench_coded_matmul,  # SPMD integration
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args(argv)

    names = [args.only] if args.only else list(SUITES)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        try:
            rows = SUITES[name].run(quick=not args.full)
        except Exception as e:  # noqa: BLE001 -- keep the suite going
            print(f"{name}/SUITE_ERROR,0.0,{type(e).__name__}: {e}")
            continue
        for row in rows:
            print(row)
        print(f"# {name} finished in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
