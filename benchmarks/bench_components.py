"""Paper Fig. 6: component times -- T1 (master->worker input transmission),
worker computation, T2 (worker->master result transmission), decode.

T1/T2 are charged from actual byte counts at an assumed link bandwidth
(1 GB/s, the OSC cluster's order of magnitude); computation is the
event-driven simulation; decode is measured.  The paper's observation: the
sparse code wins most on T2 (low recovery threshold => few results to fetch)
and on decode (peeling vs interpolation / elimination).
"""

from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from benchmarks.common import Row, sparse_bernoulli
from repro.configs.sparse_code_demo import BENCH_SQUARE
from repro.core import schemes
from repro.core.decoder import DecodingError
from repro.core.encoder import split_blocks, compute_block_products
from repro.runtime import SlowWorkers, run_coded_job

LINK_BW = 1e9  # bytes/s


def _nbytes(x) -> int:
    if sp.issparse(x):
        return x.data.nbytes + x.indices.nbytes + x.indptr.nbytes
    return x.nbytes


def run(quick: bool = True):
    exp = BENCH_SQUARE
    rng = np.random.default_rng(11)
    A = sparse_bernoulli(rng, exp.s, exp.r - exp.r % exp.m, exp.nnz_a)
    B = sparse_bernoulli(rng, exp.s, exp.t - exp.t % exp.n, exp.nnz_b)
    A_blocks = split_blocks(A, exp.m)
    B_blocks = split_blocks(B, exp.n)
    prods = compute_block_products(A_blocks, B_blocks)
    blocks = [prods[i][j] for i in range(exp.m) for j in range(exp.n)]
    a_bytes = [_nbytes(x) for x in A_blocks]
    b_bytes = [_nbytes(x) for x in B_blocks]
    blk_bytes = float(np.mean([_nbytes(x) for x in blocks]))

    m, n, N = exp.m, exp.n, exp.num_workers + 8
    strag = SlowWorkers(num_slow=exp.num_stragglers, slowdown=5.0)
    rows = []
    for sname in ("uncoded", "lt_code", "sparse_mds", "product", "polynomial",
                  "sparse_code"):
        ctor = schemes.SCHEMES[sname]
        rep = None
        for seed in range(5):  # LT peeling may stall; retry realizations
            code = ctor(m, n) if sname == "uncoded" else ctor(m, n, N, seed=seed)
            try:
                rep = run_coded_job(code, blocks, strag,
                                    rng=np.random.default_rng(5),
                                    unit_block_time=0.05)
                break
            except DecodingError:
                continue
        if rep is None:
            rows.append(Row(f"fig6/{sname}", 0.0, "UNDECODABLE in 5 realizations"))
            continue
        # T1: each worker loads the input partitions its row(s) touch
        t1 = 0.0
        for w in range(code.num_workers):
            touched_i, touched_j = set(), set()
            for r in code.worker_rows[w]:
                lo, hi = code.M.indptr[r], code.M.indptr[r + 1]
                for c in code.M.indices[lo:hi]:
                    touched_i.add(c // n)
                    touched_j.add(c % n)
            t1 = max(t1, (sum(a_bytes[i] for i in touched_i)
                          + sum(b_bytes[j] for j in touched_j)) / LINK_BW)
        # T2: results fetched from the workers actually waited on
        t2 = rep.workers_used * blk_bytes / LINK_BW
        rows.append(Row(
            f"fig6/{sname}", (t1 + rep.sim_compute_time + t2 +
                              rep.decode_wall_time) * 1e6,
            f"T1={t1:.4f}s comp={rep.sim_compute_time:.4f}s "
            f"T2={t2:.4f}s decode={rep.decode_wall_time:.4f}s"))
    return rows
