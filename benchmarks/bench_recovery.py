"""Paper Fig. 4 + Table IV: average recovery threshold versus mn.

Monte-Carlo: stream coded results one at a time; the threshold is the count
at which the collected coefficient matrix first becomes decodable.  Compares
the sparse code under Wave Soliton / Robust Soliton / LP-optimized degree
distributions against the LT code (peeling-only, unit weights) -- the paper
reports sparse-code thresholds within ~15% of the mn lower bound while LT
needs a much larger constant at practical mn.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.core import schemes
from repro.core.decoder import DecodingError, peel_schedule


def _threshold_linear(M) -> int:
    """First k such that rows 0..k-1 are full column rank."""
    d = M.shape[1]
    for k in range(d, M.shape[0] + 1):
        if np.linalg.matrix_rank(M[:k].toarray()) == d:
            return k
    return M.shape[0] + 1


def _threshold_peel(M) -> int:
    """First k such that peeling alone decodes (LT semantics)."""
    d = M.shape[1]
    for k in range(d, M.shape[0] + 1):
        try:
            peel_schedule(M[:k], check_rank=True, root_pick="fail")
            return k
        except DecodingError:
            continue
    return M.shape[0] + 1


def run(quick: bool = True):
    rows = []
    trials = 10 if quick else 40
    grid = [(2, 2), (2, 3), (3, 3), (3, 4), (4, 4)] if quick else \
           [(2, 2), (2, 3), (3, 3), (3, 4), (4, 4), (5, 5), (6, 6)]
    for m, n in grid:
        d = m * n
        N = 4 * d + 16
        for dist in ("wave_soliton", "robust_soliton", "optimized"):
            ths = []
            for t in range(trials):
                code = schemes.sparse_code(m, n, N, distribution=dist, seed=1000 + t)
                ths.append(_threshold_linear(code.M))
            avg = float(np.mean(ths))
            rows.append(Row(f"fig4/sparse[{dist}]_mn{d}", avg,
                            f"avg_threshold={avg:.2f} overhead={(avg/d-1)*100:.0f}%"))
        ths = []
        for t in range(trials):
            code = schemes.lt_code(m, n, N, seed=2000 + t)
            ths.append(_threshold_peel(code.M))
        avg = float(np.mean(ths))
        rows.append(Row(f"fig4/lt_mn{d}", avg,
                        f"avg_threshold={avg:.2f} overhead={(avg/d-1)*100:.0f}%"))
    return rows
