"""Multi-tenant serving: coded vs uncoded expert FFNs under pool faults.

One Poisson two-tenant trace is served four ways: {coded, uncoded} expert
jobs x {healthy, slow-worker, killed-worker} pools (uncoded-healthy is the
baseline; both fault scenarios reuse the same trace).  Both arms use the
SAME pool size, the same (1, n_blocks) block split of the expert weight
and the same jit trace -- only the code on the wire differs -- so the p99
gap is attributable to coding, not to extra hardware.

The paper's serving claim, quantified: with a slow worker the uncoded
token p99 absorbs the full injected delay while the coded arm decodes
from the fast prefix; with a killed worker uncoded requests FAIL (SLO
attainment 0 for affected tokens) while the coded arm completes every
request exactly, counting straggler recoveries.

Persisted under the ``serving`` key of BENCH_coded_matmul.json (merged,
read-modify-write -- never clobbers other suites' keys).
"""

from __future__ import annotations

from benchmarks.common import Row, merge_into_bench_json

NUM_WORKERS = 6
N_BLOCKS = 4          # uncoded uses workers 0..3; coded spreads over all 6
NUM_CHUNKS = 2
SLOW_WORKER = {1: 0.15}   # inside the uncoded footprint, so both arms feel it
DEAD_WORKER = (0,)


def _serve(cfg, reqs, *, coded: bool, straggler_sleep=None, dead_workers=()):
    from repro.serving.engine import ServingEngine

    eng = ServingEngine(
        cfg, coded=coded, num_workers=NUM_WORKERS, source="live",
        n_blocks=N_BLOCKS, num_chunks=NUM_CHUNKS,
        straggler_sleep=straggler_sleep, dead_workers=dead_workers,
        timeout=20.0, max_batch=3)
    with eng:
        # jit compile outside the measured loop: serving p99 is steady state
        eng.warmup(sorted({r.prompt_len for r in reqs}))
        return eng.run(reqs).summary()


def run(quick: bool = True):
    from repro.configs import ARCH_REGISTRY
    from repro.serving import SLO, TenantSpec, poisson_trace

    cfg = ARCH_REGISTRY["qwen3-moe-30b-a3b"].reduced()
    horizon = 0.25 if quick else 1.0
    tenants = [
        TenantSpec("interactive", rate=30.0, prompt_len=6,
                   max_new_tokens=2 if quick else 4,
                   slo=SLO(ttft=30.0, per_token=0.12)),
        TenantSpec("batch", rate=15.0, prompt_len=10,
                   max_new_tokens=3 if quick else 6,
                   slo=SLO(ttft=60.0, per_token=1.0)),
    ]

    def trace():
        return poisson_trace(tenants, horizon=horizon, seed=11)

    scenarios = [
        ("healthy", {}),
        ("slow_worker", {"straggler_sleep": SLOW_WORKER}),
        ("killed_worker", {"dead_workers": DEAD_WORKER}),
    ]
    results = {
        "num_workers": NUM_WORKERS, "n_blocks": N_BLOCKS,
        "num_chunks": NUM_CHUNKS, "horizon_s": horizon,
        "slow_worker_sleep_s": SLOW_WORKER, "dead_workers": list(DEAD_WORKER),
        "tenants": {t.name: {"rate": t.rate, "max_new_tokens": t.max_new_tokens,
                             "slo_per_token_s": t.slo.per_token}
                    for t in tenants},
        "arms": {},
    }
    rows = []
    for arm in ("coded", "uncoded"):
        results["arms"][arm] = {}
        for scen, kw in scenarios:
            s = _serve(cfg, trace(), coded=(arm == "coded"), **kw)
            results["arms"][arm][scen] = s
            p99 = s["token_p99_ms"]
            rows.append(Row(
                f"serving/{arm}/{scen}",
                (p99 or 0.0) * 1e3,  # us per token at p99
                f"completed={s['completed']}/{s['requests']} "
                f"slo={s['slo_attainment']:.2f} "
                f"recoveries={s['straggler_recoveries']}"))

    coded_slow = results["arms"]["coded"]["slow_worker"]
    uncoded_slow = results["arms"]["uncoded"]["slow_worker"]
    coded_kill = results["arms"]["coded"]["killed_worker"]
    uncoded_kill = results["arms"]["uncoded"]["killed_worker"]
    results["headline"] = {
        "slow_p99_ratio_uncoded_over_coded": (
            uncoded_slow["token_p99_ms"] / coded_slow["token_p99_ms"]
            if coded_slow["token_p99_ms"] else None),
        "killed_coded_completed": coded_kill["completed"],
        "killed_uncoded_completed": uncoded_kill["completed"],
    }
    rows.append(Row(
        "serving/headline", 0.0,
        f"slow p99 uncoded/coded="
        f"{results['headline']['slow_p99_ratio_uncoded_over_coded']:.2f}x; "
        f"killed: coded {coded_kill['completed']}/{coded_kill['requests']} vs "
        f"uncoded {uncoded_kill['completed']}/{uncoded_kill['requests']}"))

    merge_into_bench_json({"serving": results})
    return rows
