"""Mixture-of-Experts with sort-based capacity dispatch (expert parallel).

Tokens are routed top-k, sorted by expert id, packed into an
(E, capacity, d) buffer and run through batched expert matmuls -- so the
compiled FLOPs are proportional to *active* compute (top_k / num_experts of
dense), which is what the roofline's 6 * N_active * D model expects.  Experts
are sharded over the 'model' axis (EP); the pack/unpack gathers become
all-to-alls under GSPMD.

The expert-parallel straggler connection (DESIGN.md section 6): expert blocks
are exactly the paper's block decomposition of a distributed matmul, with
load imbalance playing the role of stragglers; `coded_moe_demo` in
examples/ applies the sparse code over expert shards.
"""

from __future__ import annotations

import contextlib
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.meshctx import maybe_shard
from repro.models.layers import ParamDef, activation


def moe_defs(cfg) -> dict:
    d = cfg.d_model
    E, ff = cfg.moe.num_experts, cfg.moe.d_ff
    return {
        "router": ParamDef((d, E), init="small_normal", spec=("data", None)),
        "w_gate": ParamDef((E, d, ff), spec=("model", "data", None)),
        "w_up": ParamDef((E, d, ff), spec=("model", "data", None)),
        "w_down": ParamDef((E, ff, d), spec=("model", None, "data")),
    }


# ---------------------- coded expert FFN (repro.coded) ----------------------
#
# The paper's code, applied over the EXPERT axis: the E per-expert products
# of one FFN matmul are the mn unknowns (m=E, n=1), encoded into
# N = coded_moe_workers weighted combinations C~_k = sum_e M[k,e] * (buf_e W_e)
# -- each a "worker" output, sharded over 'model' exactly like the plain
# expert dimension -- and decoded linearly with D = pinv(M).  Any full-rank
# survivor subset reconstructs every expert's product, so a dead or slow
# expert shard costs redundancy, not correctness.  The generator and decode
# matrices come from the SAME scheme registry as every other coded path
# (`repro.coded.plan`), so host jobs, device ops, and the MoE share one
# design per (scheme, E, N, seed).

_CODED_CTX = threading.local()


@contextlib.contextmanager
def coded_moe_decode(D):
    """Override the decode matrix coded expert FFNs use (trace-time hook).

    ``D`` is an (E, N) array -- typically
    ``coded_moe_decode_matrix(cfg, survivors)`` -- and may be a traced jit
    argument: the serving engine passes the current survivor-rebound decode
    into its jitted step so worker death re-routes decoding WITHOUT a
    retrace (shapes are survivor-independent; dead workers are zero
    columns).  Without the context the full-survivor decode constant is
    baked in and generation works standalone.
    """
    prev = getattr(_CODED_CTX, "D", None)
    _CODED_CTX.D = D
    try:
        yield
    finally:
        _CODED_CTX.D = prev


def coded_moe_num_workers(cfg) -> int:
    """N for the expert code: ``coded_moe_workers`` or E + 2."""
    n = int(getattr(cfg, "coded_moe_workers", 0) or 0)
    return n if n > 0 else cfg.moe.num_experts + 2


@functools.lru_cache(maxsize=32)
def _coded_moe_op(scheme: str, E: int, N: int, seed: int = 0):
    """The cached CodedOp designing the (m=E, n=1) expert code."""
    from repro.coded import CodedMatmulConfig, plan

    return plan(CodedMatmulConfig(scheme=scheme), m=E, n=1, num_workers=N,
                seed=seed)


def coded_moe_decode_matrix(cfg, survivors=None) -> np.ndarray:
    """(E, N) f32 decode matrix for the expert code, survivor-rebound.

    ``survivors``: optional (N,) liveness mask; dead workers become zero
    columns (the pseudo-inverse of the mask-zeroed generator), so the
    matrix shape never changes and a jitted step can take it as a plain
    argument.  Raises ``DecodingError`` when the survivors lose rank --
    eagerly, on the host, before any device step runs with a bad decode.
    """
    op = _coded_moe_op(cfg.coded.scheme, cfg.moe.num_experts,
                       coded_moe_num_workers(cfg))
    if survivors is not None:
        op = op.with_survivors(np.asarray(survivors, dtype=bool))
    return np.asarray(op.plan_.decode, dtype=np.float32)


def _coded_expert_mm(x_e, W, eq: str, cfg):
    """One expert-batched matmul through the code: encode N worker
    combinations, shard them over 'model', decode back to per-expert."""
    op = _coded_moe_op(cfg.coded.scheme, cfg.moe.num_experts,
                       coded_moe_num_workers(cfg))
    enc = jnp.asarray(
        op.base_plan.coefficient_matrix().astype(np.float32))  # (N, E)
    D = getattr(_CODED_CTX, "D", None)
    if D is None:
        D = jnp.asarray(np.asarray(op.base_plan.decode, np.float32))
    prod = jnp.einsum(eq, x_e, W).astype(jnp.float32)    # (E, C, F)
    y = jnp.einsum("ke,ecf->kcf", enc, prod)             # worker outputs
    y = maybe_shard(y, "model", None, None)
    dec = jnp.einsum("ek,kcf->ecf", jnp.asarray(D, jnp.float32), y)
    return dec.astype(x_e.dtype)


def moe_apply(x, p, cfg):
    """x: (B, S, d) -> (B, S, d).  Load-balance aux loss is returned via
    a (loss,) side value folded into the output tuple by the caller."""
    if getattr(cfg, "opt_moe_local_dispatch", False):
        return moe_apply_local(x, p, cfg)
    B, S, d = x.shape
    E = cfg.moe.num_experts
    k = cfg.moe.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    capacity = int(max(1, (T * k * cfg.moe.capacity_factor) // E))

    flat_expert = expert_ids.reshape(-1)                      # (T*k,)
    flat_gate = gate_vals.reshape(-1).astype(x.dtype)
    flat_token = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_expert)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position within expert: arange - start offset of that expert's segment
    starts = jnp.searchsorted(se, jnp.arange(E))
    pos = jnp.arange(T * k) - starts[se]
    keep = pos < capacity
    pos = jnp.where(keep, pos, 0)
    sg = jnp.where(keep, sg, 0)

    # pack: (E, C, d)
    buf = jnp.zeros((E, capacity, d), x.dtype)
    buf = buf.at[se, pos].add(jnp.where(keep[:, None], xt[st], 0))
    buf = maybe_shard(buf, "model", None, None)

    if getattr(cfg, "opt_coded_moe", False):
        h = activation(_coded_expert_mm(buf, p["w_gate"], "ecd,edf->ecf", cfg),
                       "silu")
        h = h * _coded_expert_mm(buf, p["w_up"], "ecd,edf->ecf", cfg)
        h = maybe_shard(h, "model", None, None)
        out_buf = _coded_expert_mm(h, p["w_down"], "ecf,efd->ecd", cfg)
    else:
        h = activation(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]), "silu")
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
        h = maybe_shard(h, "model", None, None)
        out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_buf = maybe_shard(out_buf, "model", None, None)

    # unpack: gather each (token, choice) result and weighted-sum into tokens
    contrib = out_buf[se, pos] * sg[:, None]                  # (T*k, d)
    out = jnp.zeros((T, d), x.dtype).at[st].add(contrib)
    out = maybe_shard(out.reshape(B, S, d), "dp", None, None)
    return out, aux


def _dp_chunks(T: int) -> int:
    """Number of token chunks = the dp degree of the active mesh (so each
    chunk's routing/pack is local to one dp shard)."""
    from repro.launch.meshctx import get_mesh
    mesh = get_mesh()
    if mesh is None:
        return 1
    dp = 1
    for ax in ("pod", "data"):
        dp *= mesh.shape.get(ax, 1)
    while T % dp:
        dp //= 2
    return max(dp, 1)


def moe_apply_local(x, p, cfg):
    """dp-chunk-local dispatch (opt_moe_local_dispatch).

    The baseline's global sort/scatter makes GSPMD replicate the (T*k, d)
    update tensor across the mesh (measured: the dominant collective cost on
    every MoE arch -- see EXPERIMENTS.md section Perf).  Here tokens are
    routed and packed *within their own dp shard*: the (X, E, Cl, d) buffer
    is produced identically on every model-column of a dp row (tokens are
    replicated across 'model'), so constraining it to ('dp', 'model', ...)
    is a pure local slice -- ZERO dispatch collectives.  The only
    communication left is the per-layer psum of the combined output, the
    same shape as a TP layer's all-reduce.
    """
    B, S, d = x.shape
    E = cfg.moe.num_experts
    k = cfg.moe.top_k
    T = B * S
    X = _dp_chunks(T)
    Tl = T // X
    xt = maybe_shard(x.reshape(X, Tl, d), "dp", None, None)

    logits = jnp.einsum("xtd,de->xte", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # (X, Tl, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    Cl = int(max(1, (Tl * k * cfg.moe.capacity_factor) // E))

    def route_chunk(xc, eids, gates):
        """One dp shard's pack: (Tl, d) -> (E, Cl, d) + unpack indices."""
        fe = eids.reshape(-1)                                 # (Tl*k,)
        fg = gates.reshape(-1).astype(xc.dtype)
        ft = jnp.repeat(jnp.arange(Tl), k)
        order = jnp.argsort(fe)
        se, st, sg = fe[order], ft[order], fg[order]
        starts = jnp.searchsorted(se, jnp.arange(E))
        pos = jnp.arange(Tl * k) - starts[se]
        keep = pos < Cl
        pos = jnp.where(keep, pos, 0)
        sg = jnp.where(keep, sg, 0)
        buf = jnp.zeros((E, Cl, d), xc.dtype)
        buf = buf.at[se, pos].add(jnp.where(keep[:, None], xc[st], 0))
        return buf, se, st, pos, sg

    buf, se, st, pos, sg = jax.vmap(route_chunk)(xt, expert_ids, gate_vals)
    buf = maybe_shard(buf, "dp", "model", None, None)         # local slice

    h = activation(jnp.einsum("xecd,edf->xecf", buf, p["w_gate"]), "silu")
    h = h * jnp.einsum("xecd,edf->xecf", buf, p["w_up"])
    h = maybe_shard(h, "dp", "model", None, None)
    out_buf = jnp.einsum("xecf,efd->xecd", h, p["w_down"])
    out_buf = maybe_shard(out_buf, "dp", "model", None, None)

    out = _combine(out_buf, se, st, pos, sg, Tl, d, E, x.dtype, cfg)
    out = maybe_shard(out, "dp", None, None)
    return out.reshape(B, S, d), aux


def _combine(out_buf, se, st, pos, sg, Tl, d, E, dtype, cfg):
    """Unpack expert outputs back to tokens.

    Default: vmapped gather + scatter-add; GSPMD turns the gather from the
    EP-sharded buffer into a masked gather + an all-reduce of the FULL
    (Tl*k, d) f32 contribution tensor -- measured as the dominant remaining
    MoE collective (EXPERIMENTS.md It.9).

    opt_moe_shardmap_combine: hand-written shard_map -- each (dp, model)
    shard gathers only ITS experts' rows, scatter-adds them into a local
    (Tl, d) partial, and ONE bf16 psum over 'model' combines the partials:
    2*k/... fewer bytes (k x from pre-summing the top-k contributions, 2x
    from bf16).
    """
    from repro.launch.meshctx import get_mesh

    mesh = get_mesh()
    X = out_buf.shape[0]
    use_shardmap = (
        getattr(cfg, "opt_moe_shardmap_combine", False)
        and mesh is not None
        and "model" in mesh.axis_names
        and E % mesh.shape["model"] == 0
    )
    if use_shardmap:
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_total = 1
        for a in dp_axes:
            dp_total *= mesh.shape[a]
        use_shardmap = X == dp_total
    if not use_shardmap:
        def combine_chunk(ob, se_c, st_c, pos_c, sg_c):
            contrib = ob[se_c, pos_c] * sg_c[:, None]          # (Tl*k, d)
            return jnp.zeros((Tl, d), dtype).at[st_c].add(contrib)
        return jax.vmap(combine_chunk)(out_buf, se, st, pos, sg)

    from jax.sharding import PartitionSpec as P

    from repro import compat

    tp = mesh.shape["model"]
    E_loc = E // tp
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def local_fn(ob, se_c, st_c, pos_c, sg_c):
        # ob: (1, E_loc, Cl, d) this shard's experts; indices replicated
        # within the dp row, (1, Tl*k) locally
        e0 = jax.lax.axis_index("model") * E_loc
        rel = se_c[0] - e0
        mine = (rel >= 0) & (rel < E_loc)
        rows = ob[0][jnp.clip(rel, 0, E_loc - 1), pos_c[0]]    # (Tl*k, d)
        contrib = jnp.where(mine[:, None], rows * sg_c[0][:, None], 0)
        partial = jnp.zeros((Tl, d), jnp.float32).at[st_c[0]].add(
            contrib.astype(jnp.float32))
        summed = jax.lax.psum(partial.astype(jnp.bfloat16), "model")
        return summed.astype(dtype)[None]

    fn = compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dp_spec, "model", None, None), P(dp_spec, None),
                  P(dp_spec, None), P(dp_spec, None), P(dp_spec, None)),
        out_specs=P(dp_spec, None, None),
        check_vma=False,
    )
    return fn(out_buf, se, st, pos, sg)
