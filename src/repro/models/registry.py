"""Unified model builder: every assigned architecture assembles from the same
slot machinery, driven purely by ArchConfig.

Layer stacking: the repeating heterogeneous unit (``cfg.layer_plan()``, e.g.
jamba's [mamba x3, attn, mamba x4] with MoE on odd slots) is one *group*;
parameters are stacked over ``num_groups`` and the model scans over groups,
so HLO size is O(group) regardless of depth -- essential for compiling 72
layers x 512 partitions on this container.

Caches: a single tree {"pos": i32, "groups": {slot_i: ...}} covers KV caches
(attention), conv+ssm states (mamba), and recurrent states (rwkv); prefill
and decode share the forward path (prefill = forward with cache at pos=0).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.launch.meshctx import maybe_shard
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    ParamDef,
    cross_entropy_chunked,
    cross_entropy_fused,
    mlp_apply,
    mlp_defs,
    norm,
    sinusoidal_positions,
    tree_init,
    tree_shapes,
    tree_specs,
)

AUX_LOSS_COEF = 0.01


def _norm_def():
    return ParamDef((0,), init="ones")  # shape patched by _slot_defs


class Model:
    """Build with repro.models.registry.build(cfg)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.plan = self._plan()

    # ------------------------------ plan -----------------------------------

    def _plan(self):
        cfg = self.cfg
        plan = cfg.layer_plan()
        if cfg.family == "encdec":
            plan = [("self_cross", f) for _, f in plan]
        return plan

    # --------------------------- param defs ---------------------------------

    def _slot_defs(self, mixer: str, ffn: str) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        nd = ParamDef((d,), init="ones")
        slot: dict = {"norm1": nd, "norm2": nd}
        if mixer == "attn":
            slot["mixer"] = attn_lib.attn_defs(cfg)
        elif mixer == "cross":
            slot["mixer"] = attn_lib.attn_defs(cfg, cross=True)
        elif mixer == "self_cross":
            slot["mixer"] = attn_lib.attn_defs(cfg)
            slot["cross"] = attn_lib.attn_defs(cfg, cross=True)
            slot["norm_x"] = nd
        elif mixer == "mamba":
            slot["mixer"] = ssm_lib.mamba_defs(cfg)
        elif mixer == "rwkv":
            slot["mixer"] = rwkv_lib.rwkv_defs(cfg)
        else:
            raise ValueError(mixer)
        if ffn == "moe":
            slot["ffn"] = moe_lib.moe_defs(cfg)
        elif mixer == "rwkv":
            slot["ffn"] = rwkv_lib.channel_mix_defs(cfg)
        else:
            slot["ffn"] = mlp_defs(d, cfg.d_ff, cfg.act, cfg.mlp_bias)
        return slot

    def param_defs(self) -> dict:
        cfg = self.cfg
        d, V = cfg.d_model, cfg.vocab_size
        G = cfg.num_groups

        def stack(defs, reps):
            return jax.tree.map(
                lambda pd: dataclasses.replace(pd, shape=(reps,) + pd.shape,
                                               spec=(None,) + tuple(pd.spec)),
                defs, is_leaf=lambda x: isinstance(x, ParamDef))

        groups = {}
        for s, (mixer, ffn) in enumerate(self.plan):
            groups[f"slot{s}"] = stack(self._slot_defs(mixer, ffn), G)

        defs: dict = {
            "embed": ParamDef((V, d), spec=("model", None)),
            "final_norm": ParamDef((d,), init="ones"),
            "groups": groups,
        }
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef((d, V), spec=(None, "model"))
        if cfg.family == "encdec":
            enc_slot = self._slot_defs("attn", "mlp")
            defs["encoder"] = stack(enc_slot, cfg.encoder_layers)
            defs["enc_final_norm"] = ParamDef((d,), init="ones")
        return defs

    def init(self, rng, dtype=jnp.float32):
        return tree_init(self.param_defs(), rng, dtype)

    def shapes(self, dtype=jnp.bfloat16):
        return tree_shapes(self.param_defs(), dtype)

    def specs(self):
        return tree_specs(self.param_defs())

    # ----------------------------- caches -----------------------------------

    def _slot_cache(self, mixer: str, batch: int, max_seq: int, dtype):
        cfg = self.cfg
        KV, hd = cfg.num_kv_heads, cfg.hd
        if mixer == "attn":
            return {"k": jnp.zeros((batch, max_seq, KV, hd), dtype),
                    "v": jnp.zeros((batch, max_seq, KV, hd), dtype)}
        if mixer == "cross":
            M = cfg.vision_tokens
            return {"mk": jnp.zeros((batch, M, KV, hd), dtype),
                    "mv": jnp.zeros((batch, M, KV, hd), dtype)}
        if mixer == "self_cross":
            M = cfg.encoder_seq
            return {"k": jnp.zeros((batch, max_seq, KV, hd), dtype),
                    "v": jnp.zeros((batch, max_seq, KV, hd), dtype),
                    "mk": jnp.zeros((batch, M, KV, hd), dtype),
                    "mv": jnp.zeros((batch, M, KV, hd), dtype)}
        if mixer == "mamba":
            return ssm_lib.mamba_init_state(cfg, batch, dtype)
        if mixer == "rwkv":
            return rwkv_lib.rwkv_init_state(cfg, batch, dtype)
        raise ValueError(mixer)

    def init_cache(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        G = self.cfg.num_groups

        def stack_tree(tree):
            return jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), tree)

        groups = {f"slot{s}": stack_tree(self._slot_cache(mixer, batch, max_seq, dtype))
                  for s, (mixer, _) in enumerate(self.plan)}
        return {"pos": jnp.int32(0), "groups": groups}

    def cache_specs(self, cache):
        """PartitionSpec tree for a cache, keyed by what each leaf is:

        KV caches (k/v/mk/mv, (G,B,S,KV,hd)): batch over dp, *sequence* over
        'model' -- flash-decode style: each TP shard attends to its slice of
        the sequence and GSPMD inserts the partial-softmax combine.  Mamba
        conv/ssm states: d_inner over 'model'.  RWKV state S: heads over
        'model'.  Non-divisible dims are replicated by the dry-run's
        sanitizer.
        """
        from repro.launch.meshctx import spec as mk

        def leaf_spec(path, a):
            names = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
            if a.ndim == 0:
                return mk()
            if names and names[-1] in ("k", "v", "mk", "mv"):
                return mk(None, "dp", "model", None, None)
            if names and names[-1] == "S":          # rwkv state (G,B,H,hs,hs)
                return mk(None, "dp", "model", None, None)
            if isinstance(names[-1], int) and a.ndim == 4 and names[-1] == 0:
                return mk(None, "dp", None, "model")   # mamba conv (G,B,dc-1,di)
            if isinstance(names[-1], int) and a.ndim == 4 and names[-1] == 1:
                return mk(None, "dp", "model", None)   # mamba h (G,B,di,ds)
            return mk(*([None, "dp"] + [None] * (a.ndim - 2)))

        return jax.tree_util.tree_map_with_path(leaf_spec, cache)

    # ---------------------------- forward ------------------------------------

    def _apply_slot(self, x, p, mixer, ffn, positions, cache, memory):
        cfg = self.cfg
        # Megatron-SP (opt_seq_parallel, training only): block outputs are
        # constrained sequence-sharded over 'model', so GSPMD lowers each TP
        # psum as a reduce-scatter (half the bytes) and the norms/residual
        # adds run sharded; the next block's first matmul all-gathers.
        sp = cache is None and getattr(cfg, "opt_seq_parallel", False)

        def out_shard(t):
            return maybe_shard(t, "dp", "model", None) if sp else t

        def tp_save(t):
            # tag TP-psum'd outputs for the remat policy (opt_remat_save_tp)
            if cache is None and getattr(cfg, "opt_remat_save_tp", False):
                from jax.ad_checkpoint import checkpoint_name
                return checkpoint_name(t, "tp_out")
            return t

        aux = jnp.float32(0)
        h = norm(x, p["norm1"], cfg.norm)
        new_cache = cache
        if mixer == "attn":
            c = None
            if cache is not None:
                c = {"k": cache["k"], "v": cache["v"], "length": positions[0]}
            out, nc = attn_lib.self_attention(h, p["mixer"], cfg, positions, cache=c)
            if cache is not None:
                new_cache = {"k": nc["k"], "v": nc["v"]}
        elif mixer == "cross":
            mem_kv = None
            if cache is not None and memory is None:
                mem_kv = (cache["mk"], cache["mv"])
            out, (mk, mv) = attn_lib.cross_attention(h, memory, p["mixer"], cfg,
                                                     mem_kv=mem_kv)
            if cache is not None:
                new_cache = {"mk": mk, "mv": mv}
        elif mixer == "self_cross":
            c = None
            if cache is not None:
                c = {"k": cache["k"], "v": cache["v"], "length": positions[0]}
            out, nc = attn_lib.self_attention(h, p["mixer"], cfg, positions, cache=c)
            x = x + out
            h = norm(x, p["norm_x"], cfg.norm)
            mem_kv = None
            if cache is not None and memory is None:
                mem_kv = (cache["mk"], cache["mv"])
            out, (mk, mv) = attn_lib.cross_attention(h, memory, p["cross"], cfg,
                                                     mem_kv=mem_kv)
            if cache is not None:
                new_cache = {"k": nc["k"], "v": nc["v"], "mk": mk, "mv": mv}
        elif mixer == "mamba":
            out, nc = ssm_lib.mamba_apply(h, p["mixer"], cfg, state=cache)
            if cache is not None:
                new_cache = nc
        elif mixer == "rwkv":
            out, nc = rwkv_lib.rwkv_apply(h, p["mixer"], cfg, state=cache)
            if cache is not None:
                new_cache = nc
        else:
            raise ValueError(mixer)
        x = x + out_shard(tp_save(out))

        h = norm(x, p["norm2"], cfg.norm)
        if ffn == "moe":
            out, aux = moe_lib.moe_apply(h, p["ffn"], cfg)
        elif mixer == "rwkv":
            last = new_cache["last_cm"] if cache is not None else None
            out, _ = rwkv_lib.channel_mix_apply(h, p["ffn"], cfg, last=last)
            if cache is not None:
                new_cache = dict(new_cache, last_cm=x[:, -1])
        else:
            out = mlp_apply(h, p["ffn"], cfg.act, cfg.mlp_bias)
        x = x + out_shard(tp_save(out))
        return x, aux, new_cache

    def _run_groups(self, x, params, positions, cache, memory):
        """Scan over the stacked groups."""
        plan = self.plan
        groups_p = params["groups"]
        groups_c = cache["groups"] if cache is not None else None

        def body(carry, xs):
            x, aux = carry
            p_g = xs[0]
            c_g = xs[1] if cache is not None else None
            new_c_g = {}
            for s, (mixer, ffn) in enumerate(plan):
                slot_c = c_g[f"slot{s}"] if c_g is not None else None
                x, a, nc = self._apply_slot(x, p_g[f"slot{s}"], mixer, ffn,
                                            positions, slot_c, memory)
                aux = aux + a
                if c_g is not None:
                    new_c_g[f"slot{s}"] = nc
            if cache is None and getattr(self.cfg, "opt_seq_parallel", False):
                # Megatron-SP: the residual stream lives sequence-sharded over
                # 'model' between blocks, so the TP all-reduce pair becomes a
                # reduce-scatter + all-gather (half the bytes) and the norms /
                # elementwise work shard too.
                x = maybe_shard(x, "dp", "model", None)
            else:
                x = maybe_shard(x, "dp", None, None)
            return (x, aux), (new_c_g if c_g is not None else 0)

        if cache is None and getattr(self.cfg, "remat", True):
            # activation checkpointing at layer-group granularity: backward
            # recomputes each group, peak activations ~ one group deep
            if getattr(self.cfg, "opt_remat_save_tp", False):
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.save_only_these_names("tp_out"))
            else:
                body = jax.checkpoint(body)
        xs = (groups_p, groups_c) if cache is not None else (groups_p,)
        (x, aux), ys = jax.lax.scan(body, (x, jnp.float32(0)), xs,
                                    unroll=getattr(self, "scan_unroll", False))
        new_groups = ys if cache is not None else None
        return x, aux, new_groups

    def _encode(self, params, frames):
        """Whisper encoder on stubbed frame embeddings (B, M, d)."""
        cfg = self.cfg
        x = frames + sinusoidal_positions(frames.shape[1], cfg.d_model
                                          ).astype(frames.dtype)
        positions = jnp.arange(frames.shape[1])

        def body(x, p_l):
            h = norm(x, p_l["norm1"], cfg.norm)
            out, _ = attn_lib.self_attention(h, p_l["mixer"], cfg, positions,
                                             causal=False)
            x = x + out
            h = norm(x, p_l["norm2"], cfg.norm)
            x = x + mlp_apply(h, p_l["ffn"], cfg.act, cfg.mlp_bias)
            return x, None

        x, _ = jax.lax.scan(body, x, params["encoder"],
                            unroll=getattr(self, "scan_unroll", False))
        return norm(x, params["enc_final_norm"], cfg.norm)

    def forward(self, params, tokens, *, extras=None, cache=None):
        """tokens: (B, S) -> hidden (B, S, d), aux, new_cache."""
        cfg = self.cfg
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        x = maybe_shard(x, "dp", None, None)
        pos0 = cache["pos"] if cache is not None else 0
        positions = pos0 + jnp.arange(S)
        if not cfg.use_rope:
            pe = sinusoidal_positions(cfg.max_seq, cfg.d_model).astype(x.dtype)
            x = x + jax.lax.dynamic_slice(pe, (pos0, 0), (S, pe.shape[1]))[None]

        memory = None
        if cfg.family == "encdec":
            if extras is not None and "frames" in extras:
                memory = self._encode(params, extras["frames"])
        elif cfg.family == "vlm":
            if extras is not None and "vision" in extras:
                memory = maybe_shard(extras["vision"], "dp", None, None)

        x, aux, new_groups = self._run_groups(x, params, positions, cache, memory)
        x = norm(x, params["final_norm"], cfg.norm)
        new_cache = None
        if cache is not None:
            new_cache = {"pos": pos0 + S, "groups": new_groups}
        return x, aux, new_cache

    # ------------------------------ heads ------------------------------------

    def head_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T  # (d, V), vocab stays sharded over model
        return params["head"]

    def logits(self, params, x):
        logits = jnp.einsum("...d,dv->...v", x, self.head_weight(params))
        return maybe_shard(logits.astype(jnp.float32), "dp", None, "model")

    def loss(self, params, batch):
        """batch: tokens (B,S), labels (B,S), [frames|vision]."""
        extras = {k: v for k, v in batch.items() if k in ("frames", "vision")}
        x, aux, _ = self.forward(params, batch["tokens"], extras=extras)
        B, S, d = x.shape
        ce = (cross_entropy_fused if getattr(self.cfg, "opt_fused_ce", False)
              else cross_entropy_chunked)
        nll = ce(
            x.reshape(B * S, d), self.head_weight(params),
            batch["labels"].reshape(-1),
            chunk=getattr(self, "ce_chunk", None) or min(4096, B * S),
            unroll=getattr(self, "scan_unroll", False))
        return nll + AUX_LOSS_COEF * aux

    def prefill(self, params, tokens, *, extras=None, cache=None,
                max_seq: int | None = None, cache_dtype=jnp.bfloat16):
        if cache is None:
            cache = self.init_cache(tokens.shape[0], max_seq or self.cfg.max_seq,
                                    cache_dtype)
        x, _, cache = self.forward(params, tokens, extras=extras, cache=cache)
        return self.logits(params, x[:, -1:]), cache

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1) -> (logits (B,1,V), cache)."""
        x, _, cache = self.forward(params, tokens, cache=cache)
        return self.logits(params, x), cache


def build(cfg) -> Model:
    return Model(cfg)
