"""Mamba selective SSM block (for the jamba hybrid).

Faithful-in-structure Mamba-1: in-proj to (x, z) of width d_inner, depthwise
causal conv, data-dependent (dt, B, C), diagonal state-space scan, gated
out-proj.  One code path covers train / prefill / decode: the causal conv
takes its left context from the carried conv state and the SSM scan starts
from the carried h -- with state=None (training) both start at zero and no
state is returned.  d_inner is sharded over 'model'.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.meshctx import maybe_shard
from repro.models.layers import ParamDef


def _dims(cfg):
    di = cfg.ssm.expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return di, dt_rank, cfg.ssm.d_state, cfg.ssm.d_conv


def mamba_defs(cfg) -> dict:
    d = cfg.d_model
    di, dt_rank, ds, dc = _dims(cfg)
    return {
        "in_proj": ParamDef((d, 2 * di), spec=("data", "model")),
        "conv_w": ParamDef((dc, di), spec=(None, "model")),
        "conv_b": ParamDef((di,), init="zeros", spec=("model",)),
        "x_proj": ParamDef((di, dt_rank + 2 * ds), spec=("model", None)),
        "dt_proj": ParamDef((dt_rank, di), spec=(None, "model")),
        "dt_bias": ParamDef((di,), init="zeros", spec=("model",)),
        "A_log": ParamDef((di, ds), init="zeros", spec=("model", None)),
        "D": ParamDef((di,), init="ones", spec=("model",)),
        "out_proj": ParamDef((di, d), spec=("model", "data")),
    }


def mamba_apply(x, p, cfg, *, state=None):
    """x: (B, S, d) -> (out (B, S, d), new_state | None).

    state: None (training) or (conv_state (B, dc-1, di), h (B, di, ds)).
    """
    B, S, d = x.shape
    di, dt_rank, ds, dc = _dims(cfg)

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)                  # (B,S,di) each
    xin = maybe_shard(xin, "dp", None, "model")

    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (di, ds)

    if state is None:
        conv_state = jnp.zeros((B, dc - 1, di), x.dtype)
        h0 = jnp.zeros((B, di, ds), jnp.float32)
    else:
        conv_state, h0 = state

    # causal depthwise conv with carried left context
    xpad = jnp.concatenate([conv_state.astype(xin.dtype), xin], axis=1)
    xc = sum(xpad[:, i:i + S] * p["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(xc + p["conv_b"])                  # (B,S,di)

    proj = jnp.einsum("bsd,dk->bsk", xc, p["x_proj"])
    dt, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt, p["dt_proj"]) + p["dt_bias"])

    def step(h, inp):
        xc_t, dt_t, B_t, C_t = inp                      # (B,di),(B,di),(B,ds),(B,ds)
        dA = jnp.exp(dt_t[..., None].astype(jnp.float32) * A)
        dBx = (dt_t * xc_t)[..., None].astype(jnp.float32) * B_t[:, None, :].astype(jnp.float32)
        h = h * dA + dBx
        y = jnp.einsum("bds,bs->bd", h, C_t.astype(jnp.float32))
        return h, y

    xs = (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)           # (B,S,di)
    y = y + xc * p["D"]
    out = jnp.einsum("bsd,de->bse", jax.nn.silu(z) * y, p["out_proj"])
    out = maybe_shard(out, "dp", None, None)

    if state is None:
        return out, None
    new_conv = xpad[:, -(dc - 1):] if dc > 1 else conv_state
    return out, (new_conv, h_fin)


def mamba_init_state(cfg, batch: int, dtype=jnp.bfloat16):
    di, _, ds, dc = _dims(cfg)
    return (jnp.zeros((batch, dc - 1, di), dtype),
            jnp.zeros((batch, di, ds), jnp.float32))
