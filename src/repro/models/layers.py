"""Shared model layers: norms, activations, RoPE, MLP, losses, param defs."""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.launch.meshctx import maybe_shard


# ------------------------------ param defs ---------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape + init + sharding spec (mesh axis names)."""

    shape: tuple[int, ...]
    init: str = "normal"        # normal | zeros | ones | small_normal
    spec: tuple = ()            # PartitionSpec axes, () = replicated
    dtype: str = "param"        # resolved by the builder (bf16/f32)

    def materialize(self, key, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        scale = 0.02 if self.init == "normal" else 0.006
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        scale = min(scale, 1.0 / math.sqrt(max(fan_in, 1)))
        return (jax.random.normal(key, self.shape) * scale).astype(dtype)


def tree_init(defs, key, dtype=jnp.float32):
    """Materialize a pytree of ParamDef into arrays (deterministic keys)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [d.materialize(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def tree_shapes(defs, dtype=jnp.float32):
    """ShapeDtypeStruct pytree (for .lower() without allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def tree_specs(defs):
    """PartitionSpec axes pytree matching tree_init/tree_shapes."""
    return jax.tree.map(lambda d: d.spec, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


# ------------------------------- norms -------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def norm(x, scale, kind: str):
    return rmsnorm(x, scale) if kind == "rmsnorm" else layernorm(x, scale)


# ----------------------------- activations ---------------------------------

def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu_sq":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(kind)


# -------------------------------- RoPE --------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# --------------------------------- MLP --------------------------------------

def mlp_apply(x, p, act: str, bias: bool):
    """SwiGLU when act == 'silu', plain two-matrix MLP otherwise."""
    if act == "silu":
        h = activation(jnp.einsum("...d,df->...f", x, p["w_gate"]), act)
        h = h * jnp.einsum("...d,df->...f", x, p["w_up"])
    else:
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        if bias:
            h = h + p["b_up"]
        h = activation(h, act)
    h = maybe_shard(h, "dp", None, "model")
    out = jnp.einsum("...f,fd->...d", h, p["w_down"])
    if bias:
        out = out + p["b_down"]
    return out


def mlp_defs(d: int, ff: int, act: str, bias: bool) -> dict:
    defs = {
        "w_up": ParamDef((d, ff), spec=("data", "model")),
        "w_down": ParamDef((ff, d), spec=("model", "data")),
    }
    if act == "silu":
        defs["w_gate"] = ParamDef((d, ff), spec=("data", "model"))
    if bias:
        defs["b_up"] = ParamDef((ff,), init="zeros", spec=("model",))
        defs["b_down"] = ParamDef((d,), init="zeros", spec=())
    return defs


# ------------------------------ LM losses -----------------------------------

def cross_entropy_chunked(x, head_w, labels, *, chunk: int = 4096,
                          logit_dtype=jnp.float32, unroll: bool = False):
    """Causal-LM cross entropy without materializing (T, V) logits.

    x: (T, d) final hidden states; head_w: (d, V) vocab-sharded over 'model';
    labels: (T,) int32.  Scans over token chunks; each chunk's logits are
    formed, reduced to (max, logsumexp, label-logit) and dropped --
    jax.checkpoint forces the backward pass to recompute them chunkwise, so
    peak memory is chunk x V / TP instead of T x V.
    Returns mean NLL (f32).
    """
    T, d = x.shape
    V = head_w.shape[-1]
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)
    nT = x.shape[0]
    xc = x.reshape(nT // chunk, chunk, d)
    lc = labels.reshape(nT // chunk, chunk)

    @jax.checkpoint
    def chunk_nll(xch, lch):
        logits = jnp.einsum("cd,dv->cv", xch, head_w).astype(logit_dtype)
        logits = maybe_shard(logits, "dp", "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lch, V, dtype=logits.dtype)
        true_logit = jnp.sum(logits * onehot, axis=-1)
        valid = (lch >= 0).astype(jnp.float32)
        return jnp.sum((lse - true_logit) * valid), jnp.sum(valid)

    def body(carry, inp):
        tot, cnt = carry
        s, c = chunk_nll(*inp)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc), unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)


# -------- fused CE: hand-written backward (beyond-paper perf path) ----------
#
# XLA's auto-transpose of the chunked CE chooses an all-gather of the f32
# dlogits chunk over the data axis before forming dW (measured: 2 x 12 GB
# per step on internlm2 train_4k).  The custom VJP below writes the exact
# backward einsums with sharding constraints, so dW comes from a local
# (tokens-sharded) contraction + a small psum of (d, V/TP) partials.

@jax.custom_vjp
def _fused_chunk_nll(xch, head_w, lch):
    s, c, _ = _fused_fwd_impl(xch, head_w, lch)
    return s, c


def _softmax_pieces(xch, head_w, lch, logit_dtype=jnp.bfloat16):
    logits = jnp.einsum("cd,dv->cv", xch, head_w).astype(jnp.float32)
    logits = maybe_shard(logits, "dp", "model")
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    expl = jnp.exp(logits - m)
    sumexp = jnp.sum(expl, axis=-1, keepdims=True)
    lse = (m + jnp.log(sumexp))[:, 0]
    V = head_w.shape[-1]
    onehot = jax.nn.one_hot(lch, V, dtype=jnp.float32)
    true_logit = jnp.sum(logits * onehot, axis=-1)
    valid = (lch >= 0).astype(jnp.float32)
    return logits, expl / sumexp, onehot, lse, true_logit, valid


def _fused_fwd_impl(xch, head_w, lch):
    _, probs, onehot, lse, true_logit, valid = _softmax_pieces(xch, head_w, lch)
    s = jnp.sum((lse - true_logit) * valid)
    c = jnp.sum(valid)
    return s, c, (probs, onehot, valid)


def _fused_fwd(xch, head_w, lch):
    s, c, _ = _fused_fwd_impl(xch, head_w, lch)
    return (s, c), (xch, head_w, lch)


def _fused_bwd(res, g):
    xch, head_w, lch = res
    gs, _ = g
    # recompute the softmax chunkwise (flash-CE style: nothing (c, V)-sized
    # was saved across chunks)
    _, probs, onehot, _, _, valid = _softmax_pieces(xch, head_w, lch)
    dlogits = (probs - onehot) * (valid * gs)[:, None]
    dlogits = maybe_shard(dlogits.astype(jnp.bfloat16), "dp", "model")
    dx = jnp.einsum("cv,dv->cd", dlogits, head_w.astype(jnp.bfloat16))
    dx = maybe_shard(dx, "dp", None).astype(xch.dtype)
    dW = jnp.einsum("cd,cv->dv", xch.astype(jnp.bfloat16), dlogits)
    dW = maybe_shard(dW, None, "model").astype(head_w.dtype)
    return dx, dW, None


_fused_chunk_nll.defvjp(_fused_fwd, _fused_bwd)


def cross_entropy_fused(x, head_w, labels, *, chunk: int = 4096,
                        unroll: bool = False):
    """Drop-in for cross_entropy_chunked with the hand-written backward."""
    T, d = x.shape
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)
    nT = x.shape[0]
    xc = x.reshape(nT // chunk, chunk, d)
    lc = labels.reshape(nT // chunk, chunk)

    def body(carry, inp):
        tot, cnt = carry
        s, c = _fused_chunk_nll(inp[0], head_w, inp[1])
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc), unroll=unroll)
    return tot / jnp.maximum(cnt, 1.0)
