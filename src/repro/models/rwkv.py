"""RWKV-6 "Finch" token-mixing block: attention-free, data-dependent decay.

Per head of size hs, the recurrent state S in R^{hs x hs} evolves as

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

with w_t = exp(-exp(decay(x_t))) a *data-dependent* per-channel decay (the
RWKV-6 novelty vs RWKV-4/5's static decay) produced by a low-rank MLP, and
token-shift interpolation on every projection input.  Linear in sequence
length -> this arch runs the long_500k shape.

Training/prefill scans over time with state (B, H, hs, hs); decode carries
(last_x, state).  Heads are sharded over 'model'.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.meshctx import maybe_shard
from repro.models.layers import ParamDef, activation


DECAY_RANK = 64


def rwkv_defs(cfg) -> dict:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    return {
        # token-shift interpolation weights for r/k/v/g/w inputs
        "mu": ParamDef((5, d), init="small_normal", spec=(None, None)),
        "wr": ParamDef((d, d), spec=("data", "model")),
        "wk": ParamDef((d, d), spec=("data", "model")),
        "wv": ParamDef((d, d), spec=("data", "model")),
        "wg": ParamDef((d, d), spec=("data", "model")),
        "wo": ParamDef((d, d), spec=("model", "data")),
        # low-rank data-dependent decay: d -> rank -> d
        "decay_a": ParamDef((d, DECAY_RANK), init="small_normal", spec=("data", None)),
        "decay_b": ParamDef((DECAY_RANK, d), init="small_normal", spec=(None, "model")),
        "decay_base": ParamDef((d,), init="zeros", spec=("model",)),
        "u": ParamDef((H, hs), init="small_normal", spec=("model", None)),
        "ln_out": ParamDef((d,), init="ones", spec=()),
    }


def channel_mix_defs(cfg) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu": ParamDef((2, d), init="small_normal", spec=(None, None)),
        "wk": ParamDef((d, ff), spec=("data", "model")),
        "wv": ParamDef((ff, d), spec=("model", "data")),
        "wr": ParamDef((d, d), spec=("data", None)),
    }


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / `last` for the first position)."""
    B, S, d = x.shape
    if S == 1:
        prev = jnp.zeros_like(x) if last is None else last[:, None]
        return prev
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if last is not None:
        shifted = shifted.at[:, 0].set(last)
    return shifted


def _mix(x, xprev, mu):
    return x + (xprev - x) * mu


def rwkv_apply(x, p, cfg, *, state=None):
    """x: (B,S,d).  state=None -> scan (training/prefill), returns (out, None);
    else state = dict(last_x (B,d), last_cm (B,d), S (B,H,hs,hs)) -> decode,
    returns (out, new_state)."""
    B, S, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs

    last_x = None if state is None else state["last_x"]
    xprev = _shift(x, last_x)
    xr = _mix(x, xprev, p["mu"][0])
    xk = _mix(x, xprev, p["mu"][1])
    xv = _mix(x, xprev, p["mu"][2])
    xg = _mix(x, xprev, p["mu"][3])
    xw = _mix(x, xprev, p["mu"][4])

    def heads(t):
        return maybe_shard(t.reshape(B, S, H, hs), "dp", None, "model", None)

    r = heads(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    k = heads(jnp.einsum("bsd,de->bse", xk, p["wk"]))
    v = heads(jnp.einsum("bsd,de->bse", xv, p["wv"]))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))
    # data-dependent decay in (0, 1): w = exp(-exp(lora(xw) + base))
    dec = jnp.einsum("bsd,dr->bsr", xw, p["decay_a"])
    dec = jnp.einsum("bsr,rd->bsd", jnp.tanh(dec), p["decay_b"]) + p["decay_base"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(B, S, H, hs)

    u = p["u"].astype(jnp.float32)

    def step(Sst, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hs) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t).astype(jnp.float32)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       Sst + u[None, :, :, None] * kv)
        Sst = w_t[..., None] * Sst + kv
        return Sst, o

    if state is None:
        S0 = jnp.zeros((B, H, hs, hs), jnp.float32)
    else:
        S0 = state["S"]

    seq = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
           k.transpose(1, 0, 2, 3).astype(jnp.float32),
           v.transpose(1, 0, 2, 3).astype(jnp.float32),
           w.transpose(1, 0, 2, 3))
    S_fin, os = jax.lax.scan(step, S0, seq)
    o = os.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)

    # group norm over heads (approximated by rmsnorm on the full vector)
    var = jnp.mean(jnp.square(o.reshape(B, S, H, hs).astype(jnp.float32)),
                   axis=-1, keepdims=True)
    o = (o.reshape(B, S, H, hs) * jax.lax.rsqrt(var + 1e-6)).reshape(B, S, d)
    o = o.astype(x.dtype) * p["ln_out"]
    out = jnp.einsum("bsd,de->bse", o * g, p["wo"])
    out = maybe_shard(out, "dp", None, None)

    if state is None:
        return out, None
    new_state = {"last_x": x[:, -1], "last_cm": state["last_cm"], "S": S_fin}
    return out, new_state


def channel_mix_apply(x, p, cfg, *, last=None):
    """RWKV channel mix (the arch's FFN): relu^2 with receptance gate.
    Returns (out, new_last)."""
    xprev = _shift(x, last)
    xk = _mix(x, xprev, p["mu"][0])
    xr = _mix(x, xprev, p["mu"][1])
    kk = activation(jnp.einsum("bsd,df->bsf", xk, p["wk"]), "relu_sq")
    kk = maybe_shard(kk, "dp", None, "model")
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv"])
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]))
    return rr * vv, (x[:, -1] if last is not None else None)


def rwkv_init_state(cfg, batch: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    return {
        "last_x": jnp.zeros((batch, d), dtype),
        "last_cm": jnp.zeros((batch, d), dtype),
        "S": jnp.zeros((batch, H, hs, hs), jnp.float32),
    }
