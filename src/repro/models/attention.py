"""GQA attention (self / cross), with RoPE, biases, KV caches.

Sharding: heads over 'model', batch over 'dp'.  GSPMD pads non-divisible
head counts (qwen2: 28, starcoder2: 36 over TP=16) -- the padding waste is
surfaced in the roofline's MODEL_FLOPS/HLO ratio.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.meshctx import maybe_shard
from repro.models.layers import ParamDef, apply_rope

NEG_INF = -2.0 ** 30


def attn_defs(cfg, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.num_heads, cfg.num_kv_heads
    defs = {
        "wq": ParamDef((d, H * hd), spec=("data", "model")),
        "wk": ParamDef((d, KV * hd), spec=("data", "model")),
        "wv": ParamDef((d, KV * hd), spec=("data", "model")),
        "wo": ParamDef((H * hd, d), spec=("model", "data")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H * hd,), init="zeros", spec=("model",))
        defs["bk"] = ParamDef((KV * hd,), init="zeros", spec=("model",))
        defs["bv"] = ParamDef((KV * hd,), init="zeros", spec=("model",))
    return defs


def _project(x, p, cfg, heads, name):
    out = jnp.einsum("...d,dh->...h", x, p[f"w{name}"])
    if cfg.qkv_bias and name in ("q", "k", "v"):
        out = out + p[f"b{name}"]
    *lead, _ = out.shape
    out = out.reshape(*lead, heads, cfg.hd)
    # GQA-TP: shard the head axis only when it divides the TP degree;
    # otherwise keep K/V replicated over 'model' (cheaper than the
    # conflicting-sharding repartition GSPMD would emit).
    from repro.launch.meshctx import get_mesh
    mesh = get_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if heads % tp == 0:
        return maybe_shard(out, "dp", None, "model", None)
    return maybe_shard(out, "dp", None, None, None)


def _sdpa(q, k, v, mask=None):
    """q: (B,S,H,hd)  k/v: (B,T,KV,hd); GQA by head-group broadcast."""
    B, S, H, hd = q.shape
    _, T, KV, _ = k.shape
    rep = H // KV
    qg = q.reshape(B, S, KV, rep, hd)
    scores = jnp.einsum("bskrh,btkh->bkrst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkrst,btkh->bskrh", probs, v)
    return out.reshape(B, S, H, hd)


def self_attention(x, p, cfg, positions, *, causal: bool = True, cache=None):
    """Returns (out, new_cache).  cache = dict(k, v, length) for decode."""
    B, S, d = x.shape
    q = _project(x, p, cfg, cfg.num_heads, "q")
    k = _project(x, p, cfg, cfg.num_kv_heads, "k")
    v = _project(x, p, cfg, cfg.num_kv_heads, "v")
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # decode: append this step's k/v at position `length`
        length = cache["length"]
        if getattr(cfg, "opt_onehot_cache", False) and S == 1:
            # one-hot masked update: elementwise, so a sequence-sharded cache
            # stays fully local (a dynamic-update-slice at a traced position
            # makes GSPMD re-materialize the whole cache -- the dominant
            # decode collective in the baseline; see EXPERIMENTS.md Perf)
            T = cache["k"].shape[1]
            hot = (jnp.arange(T) == length).astype(cache["k"].dtype)
            hot = hot[None, :, None, None]
            ck = cache["k"] * (1 - hot) + k.astype(cache["k"].dtype) * hot
            cv = cache["v"] * (1 - hot) + v.astype(cache["v"].dtype) * hot
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, length, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, length, 0, 0))
        new_cache = {"k": ck, "v": cv, "length": length + S}
        k, v = ck, cv
        T = k.shape[1]
        tpos = jnp.arange(T)
        mask = (tpos[None, :] <= (length + jnp.arange(S))[:, None])  # (S, T)
        mask = mask[None, None, None, :, :]
    elif causal:
        tpos = jnp.arange(S)
        mask = (tpos[None, :] <= tpos[:, None])[None, None, None, :, :]
    else:
        mask = None

    out = _sdpa(q, k, v, mask)
    out = jnp.einsum("...h,hd->...d", out.reshape(B, S, -1), p["wo"])
    return maybe_shard(out, "dp", None, None), new_cache


def cross_attention(x, memory, p, cfg, *, mem_kv=None):
    """x: (B,S,d) queries; memory: (B,M,d) (encoder output / image tokens).

    mem_kv: optional precomputed (k, v) of the memory (decode-time reuse).
    Returns (out, (k, v)).
    """
    q = _project(x, p, cfg, cfg.num_heads, "q")
    if mem_kv is None:
        k = _project(memory, p, cfg, cfg.num_kv_heads, "k")
        v = _project(memory, p, cfg, cfg.num_kv_heads, "v")
    else:
        k, v = mem_kv
    out = _sdpa(q, k, v, mask=None)
    B, S = x.shape[:2]
    out = jnp.einsum("...h,hd->...d", out.reshape(B, S, -1), p["wo"])
    return maybe_shard(out, "dp", None, None), (k, v)


def init_kv_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    KV, hd = cfg.num_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, max_seq, KV, hd), dtype),
        "v": jnp.zeros((batch, max_seq, KV, hd), dtype),
        "length": jnp.int32(0),
    }
