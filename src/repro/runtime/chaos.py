"""Declarative fault plans for the out-of-process runtime (DESIGN.md sec 10).

The thread runtime can only *simulate* stragglers (injected sleeps inside one
GIL-sharing process); the process runtime (``runtime.procpool``) promotes
workers to real OS subprocesses, and this module injects real faults into
them:

* ``kill(w, after_chunk=c)``    -- SIGKILL worker w when its chunk c arrives
                                   at the master (so it dies mid-chunk c+1);
                                   ``after_chunk=None`` kills at spawn.
* ``pause(w, after_chunk=c)``   -- SIGSTOP on the same trigger; with
                                   ``duration=d`` a timer sends SIGCONT d
                                   seconds later, otherwise the worker stays
                                   frozen until pool shutdown.  A pause
                                   longer than the master's heartbeat
                                   deadline is indistinguishable from a hang
                                   -- which is the point.
* ``slow(w, factor=f)``         -- throttle worker w to ~1/f of real time by
                                   duty-cycling SIGSTOP/SIGCONT (run 1 slice,
                                   freeze f-1 slices).  A genuine slowdown:
                                   the OS deschedules the process, no
                                   cooperation from worker code.
* ``drop_result(w, chunk=c)``   -- the master discards worker w's chunk-c
                                   message on arrival (a lost message).  Sub-
                                   task streams are ordered, so the drop
                                   severs w's stream: later chunks of w are
                                   not consumable and w stops being expected.

A ``FaultPlan`` is just a tuple of these; ``FaultInjector`` executes it
against live worker pids from the master side, recording every action in a
``FaultLedger`` that the pool extends with what the master *observed* (crash
exit codes, missed heartbeat deadlines, respawns) and ``run_proc_job``
finalizes with the per-worker equation loss/recovery accounting.

``FaultRealization`` maps the same plan onto the event-driven simulator's
chunk timeline, so ``run_coded_job`` predicts the recovery time of the exact
fault realization ``run_proc_job`` executes for real -- the comparison
``benchmarks/bench_chaos.py`` persists into BENCH_coded_matmul.json.

Signals are POSIX-only; constructing a plan that needs them raises on other
platforms rather than degrading silently.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import threading
import time

import numpy as np

from repro.runtime.straggler import StragglerModel

FAULT_KINDS = ("kill", "pause", "slow", "drop_result")

#: run-slice length of the slow() duty cycle, seconds.  One slice runs, then
#: (factor - 1) slices are spent SIGSTOPped, so the long-run service rate is
#: 1/factor of nominal.
SLOW_SLICE = 0.05


def _require_posix_signals() -> None:
    if not hasattr(signal, "SIGSTOP"):  # pragma: no cover - non-POSIX only
        raise RuntimeError(
            "chaos faults drive SIGSTOP/SIGCONT/SIGKILL and need a POSIX "
            "platform")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned fault.  Use the ``kill``/``pause``/``slow``/
    ``drop_result`` constructors instead of instantiating directly."""

    kind: str
    worker: int
    after_chunk: int | None = None   # trigger on this chunk's arrival (kill/pause)
    duration: float | None = None    # pause: seconds until SIGCONT (None = never)
    factor: float = 1.0              # slow: throttle factor
    chunk: int | None = None         # drop_result: which chunk message is lost

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.worker < 0:
            raise ValueError(f"worker must be >= 0, got {self.worker}")
        if self.kind == "slow" and self.factor <= 1.0:
            raise ValueError(f"slow factor must be > 1, got {self.factor}")
        if self.kind == "drop_result" and self.chunk is None:
            raise ValueError("drop_result needs the chunk to drop")


def kill(worker: int, after_chunk: int | None = None) -> Fault:
    """SIGKILL ``worker`` when its chunk ``after_chunk`` arrives (None: at
    spawn).  The death is real -- exit code -SIGKILL, pipe EOF mid-stream."""
    _require_posix_signals()
    return Fault(kind="kill", worker=worker, after_chunk=after_chunk)


def pause(worker: int, after_chunk: int | None = None,
          duration: float | None = None) -> Fault:
    """SIGSTOP ``worker`` on the trigger; SIGCONT after ``duration`` seconds
    (None: frozen until shutdown).  Freezes heartbeats too, so a pause past
    the master's deadline is detected exactly like a hang."""
    _require_posix_signals()
    return Fault(kind="pause", worker=worker, after_chunk=after_chunk,
                 duration=duration)


def slow(worker: int, factor: float = 10.0) -> Fault:
    """Throttle ``worker`` to ~1/factor speed by SIGSTOP/SIGCONT duty
    cycling from spawn onward."""
    _require_posix_signals()
    return Fault(kind="slow", worker=worker, factor=float(factor))


def drop_result(worker: int, chunk: int) -> Fault:
    """Lose ``worker``'s ``chunk`` message at the master.  Ordered sub-task
    streams mean the drop severs the rest of the worker's stream."""
    return Fault(kind="drop_result", worker=worker, chunk=int(chunk))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A declarative set of faults, validated against the job's geometry."""

    faults: tuple[Fault, ...] = ()

    @classmethod
    def coerce(cls, plan) -> "FaultPlan":
        if plan is None:
            return cls()
        if isinstance(plan, FaultPlan):
            return plan
        if isinstance(plan, Fault):
            return cls(faults=(plan,))
        return cls(faults=tuple(plan))

    @property
    def workers(self) -> list[int]:
        return sorted({f.worker for f in self.faults})

    def validate(self, num_workers: int, num_chunks: int) -> None:
        for f in self.faults:
            if f.worker >= num_workers:
                raise ValueError(
                    f"fault {f.kind} targets worker {f.worker}, job has "
                    f"{num_workers}")
            trigger = f.chunk if f.kind == "drop_result" else f.after_chunk
            if trigger is not None and not 0 <= trigger < num_chunks:
                raise ValueError(
                    f"fault {f.kind} triggers on chunk {trigger}, job has "
                    f"{num_chunks} chunks per worker")


class FaultLedger:
    """Chronological record of injected faults and master-side observations.

    Entries are plain dicts (JSON-friendly, they land verbatim on
    ``ExecutionReport.fault_ledger``): ``{"t": seconds since job start,
    "kind": ..., "worker": ...}`` plus kind-specific detail.  Terminal
    entries (crash/drop/deadline) gain ``equations_recovered`` /
    ``equations_lost`` when ``run_proc_job`` finalizes the ledger against
    the consumed chunk prefixes.
    """

    def __init__(self):
        self.t0 = time.perf_counter()
        self.entries: list[dict] = []
        self._lock = threading.Lock()  # injector timers record concurrently

    def record(self, kind: str, worker: int, **detail) -> dict:
        entry = {"t": round(time.perf_counter() - self.t0, 6),
                 "kind": kind, "worker": int(worker), **detail}
        with self._lock:
            self.entries.append(entry)
        return entry

    def workers(self) -> list[int]:
        with self._lock:
            return sorted({e["worker"] for e in self.entries})

    def summary(self) -> dict:
        """Compact rollup for ``ExecutionReport.decode_stats['faults']``."""
        with self._lock:
            by_kind: dict[str, int] = {}
            for e in self.entries:
                by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
            return {
                "events": len(self.entries),
                "by_kind": by_kind,
                "workers": sorted({e["worker"] for e in self.entries}),
                "equations_lost": sum(e.get("equations_lost", 0)
                                      for e in self.entries),
                "equations_recovered": sum(e.get("equations_recovered", 0)
                                           for e in self.entries),
            }


class FaultInjector:
    """Executes a ``FaultPlan`` against live worker pids (master side).

    The pool calls ``on_spawn`` when a worker's hello arrives (pid known),
    ``should_drop``/``on_result`` per chunk arrival, and ``shutdown`` when
    the job ends.  Every fault fires at most once, so a respawned worker is
    not re-killed by the fault that already claimed its predecessor.
    """

    def __init__(self, plan: FaultPlan, ledger: FaultLedger):
        self.plan = plan
        self.ledger = ledger
        self._pids: dict[int, int] = {}
        self._fired: set[int] = set()          # indices into plan.faults
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._paused_pids: set[int] = set()

    # ------------------------------ triggers ------------------------------

    def on_spawn(self, worker: int, pid: int) -> None:
        self._pids[worker] = pid
        for i, f in self._pending(worker):
            if f.kind == "slow":
                self._fired.add(i)
                self.ledger.record("slow", worker, factor=f.factor, pid=pid)
                t = threading.Thread(target=self._throttle,
                                     args=(pid, f.factor), daemon=True)
                t.start()
                self._threads.append(t)
            elif f.after_chunk is None and f.kind in ("kill", "pause"):
                self._fire(i, f, pid)

    def on_result(self, worker: int, chunk: int) -> None:
        pid = self._pids.get(worker)
        if pid is None:  # pragma: no cover - hello always precedes chunks
            return
        for i, f in self._pending(worker):
            if f.kind in ("kill", "pause") and f.after_chunk == chunk:
                self._fire(i, f, pid)

    def should_drop(self, worker: int, chunk: int) -> bool:
        for i, f in self._pending(worker):
            if f.kind == "drop_result" and f.chunk == chunk:
                self._fired.add(i)
                self.ledger.record("drop_result", worker, chunk=chunk)
                return True
        return False

    def _pending(self, worker: int):
        return [(i, f) for i, f in enumerate(self.plan.faults)
                if f.worker == worker and i not in self._fired]

    def _fire(self, i: int, f: Fault, pid: int) -> None:
        self._fired.add(i)
        if f.kind == "kill":
            self.ledger.record("kill", f.worker, after_chunk=f.after_chunk,
                               pid=pid)
            _signal(pid, signal.SIGKILL)
        elif f.kind == "pause":
            self.ledger.record("pause", f.worker, after_chunk=f.after_chunk,
                               duration=f.duration, pid=pid)
            if _signal(pid, signal.SIGSTOP):
                self._paused_pids.add(pid)
                if f.duration is not None:
                    t = threading.Thread(
                        target=self._resume_later,
                        args=(f.worker, pid, f.duration), daemon=True)
                    t.start()
                    self._threads.append(t)

    # ----------------------------- machinery ------------------------------

    def _resume_later(self, worker: int, pid: int, duration: float) -> None:
        if self._stop.wait(duration):
            return  # shutdown resumes every paused pid itself
        if _signal(pid, signal.SIGCONT):
            self._paused_pids.discard(pid)
            self.ledger.record("resume", worker, pid=pid)

    def _throttle(self, pid: int, factor: float) -> None:
        """Duty-cycle SIGSTOP/SIGCONT: run one slice, freeze factor-1."""
        while not self._stop.wait(SLOW_SLICE):
            if not _signal(pid, signal.SIGSTOP):
                return
            stopped = self._stop.wait(SLOW_SLICE * (factor - 1.0))
            if not _signal(pid, signal.SIGCONT):
                return
            if stopped:
                return

    def shutdown(self) -> None:
        """Stop throttle/timer threads and unfreeze every paused pid so the
        pool can terminate its processes cleanly."""
        self._stop.set()
        for t in self._threads:
            t.join(timeout=1.0)
        for pid in list(self._paused_pids) + list(self._pids.values()):
            _signal(pid, signal.SIGCONT)


def _signal(pid: int, sig) -> bool:
    try:
        os.kill(pid, sig)
        return True
    except (ProcessLookupError, PermissionError):
        return False


# --------------------- the simulator twin of a plan -------------------------

@dataclasses.dataclass
class FaultRealization(StragglerModel):
    """The same fault plan on the simulator's chunk timeline.

    ``run_coded_job`` with this model predicts the recovery behaviour of the
    realization ``run_proc_job`` executes for real: every worker serves its
    chunks at unit rate (scaled by ``unit_block_time``), then the plan edits
    the timeline --

    * ``slow``        -> the worker's per-chunk durations stretch by factor;
    * ``kill``        -> chunks after ``after_chunk`` never arrive (+inf);
    * ``pause``       -> chunks after the trigger shift by ``duration``
                         (+inf when the pause never ends);
    * ``drop_result`` -> the dropped chunk and everything after it never
                         arrive (the ordered stream is severed at the loss).

    The master's decodable-prefix rule then yields the predicted recovery
    point, with the identical arrival-set semantics the process pool's event
    source enforces.
    """

    plan: FaultPlan = dataclasses.field(default_factory=FaultPlan)

    def chunk_completion_times(self, work, rng):
        work = np.asarray(work, dtype=np.float64)
        if work.ndim != 2:
            raise ValueError(f"work must be (N, q), got shape {work.shape}")
        durations = work.copy()
        shifts = np.zeros_like(work)
        cut = np.full(work.shape[0], work.shape[1] + 1)  # first never-arriving chunk
        for f in self.plan.faults:
            w = f.worker
            if w >= work.shape[0]:
                raise ValueError(
                    f"fault targets worker {w}, realization has "
                    f"{work.shape[0]}")
            if f.kind == "slow":
                durations[w] *= f.factor
            elif f.kind == "kill":
                first = 0 if f.after_chunk is None else f.after_chunk + 1
                cut[w] = min(cut[w], first)
            elif f.kind == "pause":
                first = 0 if f.after_chunk is None else f.after_chunk + 1
                if f.duration is None:
                    cut[w] = min(cut[w], first)
                else:
                    shifts[w, first:] += f.duration
            elif f.kind == "drop_result":
                cut[w] = min(cut[w], f.chunk)
        times = np.cumsum(durations, axis=1) + shifts
        for w in range(work.shape[0]):
            if cut[w] <= work.shape[1]:
                times[w, int(cut[w]):] = np.inf
        return times

    def completion_times(self, nominal, rng):
        nominal = np.asarray(nominal, dtype=np.float64)
        return self.chunk_completion_times(nominal[:, None], rng)[:, -1]
