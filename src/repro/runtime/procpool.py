"""Out-of-process worker pool: real subprocesses, real faults, one protocol.

``run_live_job`` runs workers as daemon threads -- they share a GIL and a
fate, so a "straggler" is an injected sleep and a "dead worker" is a thought
experiment.  This module promotes workers to spawn-started OS subprocesses
with a per-worker pipe transport and serializes their ``(worker, chunk,
payload)`` arrivals into the SAME master loop
(``runtime.executor._consume_events``) the simulator and the thread runtime
feed -- the event-source abstraction of DESIGN.md section 8 holds; only the
transport changed.  What the process boundary buys (DESIGN.md section 10):

* workers can actually crash (SIGKILL mid-chunk -> pipe EOF + exit code),
  hang (SIGSTOP freezes compute *and* heartbeats), or genuinely run slow
  (duty-cycled SIGSTOP/SIGCONT) -- see ``runtime.chaos`` for the fault plan
  language;
* the master grows the robustness a thread pool never needed: per-worker
  heartbeats with a deadline (an overdue worker stops being waited on but
  its late arrivals still count), crash detection via pipe EOF + exit code,
  optional one-shot respawn that reassigns a dead worker's remaining chunk
  suffix to a fresh process, and graceful degradation to decoding from
  whatever ordered chunk prefixes survived;
* every fault -- injected or observed -- lands in a ``FaultLedger`` that
  ``ExecutionReport.fault_ledger`` exposes, with terminal entries accounting
  equations lost vs recovered.

Wire format (master <- worker, pickled tuples over one simplex pipe per
worker): ``("hello", w, pid)`` once at start, ``("hb", w)`` every heartbeat
interval from a daemon thread (so beats keep flowing during a long chunk but
stop when the process is frozen or dead), ``("chunk", w, c, payload)`` per
completed chunk in order, ``("done", w)`` before a clean exit.  Pipe EOF
without ``done`` is a crash, whatever the exit code says.
"""

from __future__ import annotations

import dataclasses
import multiprocessing
import os
import threading
import time
from multiprocessing import connection as mp_connection

import numpy as np

from repro.core.encoder import encode_blocks, make_tasks
from repro.core.schemes import CodeInstance
from repro.runtime.chaos import FaultInjector, FaultLedger, FaultPlan
from repro.runtime.executor import (
    ExecutionReport,
    _EventSourceDry,
    _consume_events,
)

#: master poll cadence, seconds: the wait() timeout between liveness sweeps
_POLL = 0.02


# ------------------------------- worker side --------------------------------

def _worker_main(worker, conn, row_chunks, A_blocks, B_blocks, n,
                 num_chunks, start_chunk, chunk_sleep, hb_interval):
    """Subprocess entry point (spawn target; must stay module-level).

    Computes the worker's ordered chunk stream exactly like the thread
    runtime's ``worker_fn`` and sends each result over the pipe.  A daemon
    heartbeat thread shares the connection under a lock: beats prove the
    *process* is scheduled, independent of chunk progress.
    """
    send_lock = threading.Lock()

    def _send(msg) -> bool:
        try:
            with send_lock:
                conn.send(msg)
            return True
        except (BrokenPipeError, OSError, ValueError):
            return False  # master went away: nothing left to report to

    _send(("hello", worker, os.getpid()))
    stop_hb = threading.Event()

    def _beat():
        while not stop_hb.wait(hb_interval):
            if not _send(("hb", worker)):
                return

    threading.Thread(target=_beat, daemon=True).start()
    try:
        for c in range(start_chunk, num_chunks):
            if chunk_sleep:
                time.sleep(chunk_sleep)
            payload = {}
            for r, chunks in row_chunks.items():
                out = encode_blocks(chunks[c], A_blocks, B_blocks, n)
                if out is not None:
                    payload[r * num_chunks + c] = out
            if not _send(("chunk", worker, c, payload)):
                return
        _send(("done", worker))
    finally:
        stop_hb.set()
        conn.close()


# ------------------------------- master side --------------------------------

@dataclasses.dataclass
class _WorkerState:
    """Master-side view of one worker process's lifecycle."""

    proc: object
    conn: object                  # recv end; None once EOF'd/severed
    pid: int | None = None
    last_seen: float = 0.0        # perf_counter of the last message
    next_chunk: int = 0           # next in-order chunk the master will accept
    done: bool = False            # clean "done" sentinel received
    dead: bool = False            # EOF before done (crash)
    overdue: bool = False         # missed the heartbeat deadline
    dropped: bool = False         # stream severed by a drop_result fault
    respawned: bool = False       # one-shot respawn already spent


class ProcPool:
    """Spawn-based worker pool whose ``events()`` iterator is a master-loop
    event source (the third transport after simulation and threads)."""

    def __init__(self, code: CodeInstance, num_chunks: int,
                 A_blocks, B_blocks, n: int, *,
                 straggler_sleep: dict[int, float] | None = None,
                 heartbeat_interval: float = 0.05,
                 heartbeat_deadline: float = 2.0,
                 respawn: bool = False,
                 plan=None):
        self.code = code
        self.num_chunks = int(num_chunks)
        self.A_blocks, self.B_blocks, self.n = A_blocks, B_blocks, n
        self.straggler_sleep = dict(straggler_sleep or {})
        self.hb_interval = float(heartbeat_interval)
        self.hb_deadline = float(heartbeat_deadline)
        self.respawn = bool(respawn)
        if self.hb_deadline <= self.hb_interval:
            raise ValueError("heartbeat_deadline must exceed the interval")

        self.ledger = FaultLedger()
        plan = FaultPlan.coerce(plan)
        plan.validate(code.num_workers, self.num_chunks)
        self.injector = FaultInjector(plan, self.ledger)

        self._ctx = multiprocessing.get_context("spawn")
        tasks_by_row = {t.worker: t for t in make_tasks(code.M)}
        self._row_chunks = {
            w: {r: tasks_by_row[r].chunks(self.num_chunks)
                for r in code.worker_rows[w]}
            for w in range(code.num_workers)
        }
        self._workers: dict[int, _WorkerState] = {}
        self._t0 = 0.0

    # ------------------------------ lifecycle -----------------------------

    def start(self) -> float:
        self._t0 = time.perf_counter()
        self.ledger.t0 = self._t0
        for w in range(self.code.num_workers):
            self._spawn(w, 0)
        return self._t0

    def _spawn(self, w: int, start_chunk: int, respawned: bool = False):
        recv_end, send_end = self._ctx.Pipe(duplex=False)
        chunk_sleep = self.straggler_sleep.get(w, 0.0) / self.num_chunks
        proc = self._ctx.Process(
            target=_worker_main,
            args=(w, send_end, self._row_chunks[w], self.A_blocks,
                  self.B_blocks, self.n, self.num_chunks, start_chunk,
                  chunk_sleep, self.hb_interval),
            daemon=True, name=f"proc-worker-{w}")
        proc.start()
        send_end.close()  # keep only the child's copy: EOF tracks its death
        self._workers[w] = _WorkerState(
            proc=proc, conn=recv_end, last_seen=time.perf_counter(),
            next_chunk=start_chunk, respawned=respawned)

    def shutdown(self) -> None:
        """Injector off, every child unfrozen/terminated/reaped, pipes
        closed.  Idempotent; safe after partial startup."""
        self.injector.shutdown()
        for st in self._workers.values():
            if st.proc.is_alive():
                st.proc.terminate()
        deadline = time.perf_counter() + 5.0
        for st in self._workers.values():
            st.proc.join(timeout=max(0.1, deadline - time.perf_counter()))
            if st.proc.is_alive():  # pragma: no cover - SIGKILL backstop
                st.proc.kill()
                st.proc.join(timeout=1.0)
            if st.conn is not None:
                st.conn.close()
                st.conn = None

    # ----------------------------- event source ---------------------------

    def events(self, timeout: float):
        """Yield ``(time, worker, chunk, payload)`` for ``_consume_events``.

        Ends (StopIteration) only when every worker delivered every chunk;
        raises ``_EventSourceDry`` when the survivors' arrivals are drained
        but some stream ended early (crash/drop/overdue), or when nothing
        arrives for ``timeout`` seconds -- the master then decides whether
        the collected prefixes decode anyway.
        """
        last_progress = time.perf_counter()
        while True:
            conns = {st.conn: w for w, st in self._workers.items()
                     if st.conn is not None}
            if conns:
                ready = mp_connection.wait(list(conns), timeout=_POLL)
            else:
                time.sleep(_POLL)
                ready = []
            now = time.perf_counter()
            for conn in ready:
                w = conns[conn]
                for evt in self._drain(w, now):
                    last_progress = time.perf_counter()
                    yield evt
            self._sweep_deadlines(time.perf_counter())
            if not self._expecting():
                shortfall = self._shortfall_reason()
                if shortfall:
                    raise _EventSourceDry(shortfall)
                return
            if time.perf_counter() - last_progress > timeout:
                raise _EventSourceDry(
                    f"no worker result within {timeout:.1f}s and the "
                    "collected chunks do not decode (hung or dead workers?)")

    def _drain(self, w: int, now: float):
        """Consume every buffered message of worker ``w``; yield its in-order
        chunk events.  EOF classifies the exit (done vs crash) only after the
        buffer is empty, so a respawn never resends a chunk the dead
        incarnation already delivered."""
        st = self._workers[w]
        while st.conn is not None and st.conn.poll():
            try:
                msg = st.conn.recv()
            except (EOFError, OSError, ValueError):
                self._on_eof(w, st, now)
                return
            st.last_seen = now
            tag = msg[0]
            if tag == "hello":
                st.pid = msg[2]
                self.injector.on_spawn(w, st.pid)
            elif tag == "chunk":
                _, _, c, payload = msg
                if st.dropped:
                    continue  # severed stream: later chunks are out of order
                if self.injector.should_drop(w, c):
                    st.dropped = True
                    continue
                st.next_chunk = c + 1
                self.injector.on_result(w, c)
                yield now - self._t0, w, c, payload
            elif tag == "done":
                st.done = True
            # "hb" only refreshes last_seen, handled above

    def _on_eof(self, w: int, st: _WorkerState, now: float) -> None:
        st.conn.close()
        st.conn = None
        st.proc.join(timeout=0.5)  # reap; the write end is gone already
        if st.done:
            return
        st.dead = True
        self.ledger.record(
            "crash_detected", w, exitcode=st.proc.exitcode,
            next_chunk=st.next_chunk)
        if (self.respawn and not st.respawned
                and st.next_chunk < self.num_chunks):
            self.ledger.record("respawn", w, start_chunk=st.next_chunk)
            self._spawn(w, st.next_chunk, respawned=True)

    def _sweep_deadlines(self, now: float) -> None:
        for w, st in self._workers.items():
            # the deadline clock starts at hello: interpreter startup in the
            # child (pid still unknown) must not read as a missed heartbeat
            if (st.conn is None or st.pid is None or st.done or st.overdue
                    or st.next_chunk >= self.num_chunks):
                continue
            if now - st.last_seen > self.hb_deadline:
                st.overdue = True
                self.ledger.record(
                    "deadline_missed", w,
                    silent_for=round(now - st.last_seen, 6),
                    next_chunk=st.next_chunk)

    def _expecting(self) -> bool:
        """Is any worker still worth waiting on?"""
        return any(
            st.conn is not None and not (st.done or st.overdue or st.dropped)
            and st.next_chunk < self.num_chunks
            for st in self._workers.values())

    def _shortfall_reason(self) -> str | None:
        """Human-readable cause when not every chunk arrived, else None."""
        crashed = sorted(w for w, st in self._workers.items() if st.dead)
        dropped = sorted(w for w, st in self._workers.items() if st.dropped)
        overdue = sorted(
            w for w, st in self._workers.items()
            if st.overdue and st.next_chunk < self.num_chunks)
        parts = []
        if crashed:
            parts.append(f"worker process(es) {crashed} crashed")
        if dropped:
            parts.append(f"result stream(s) of {dropped} severed by a "
                         "dropped message")
        if overdue:
            parts.append(f"worker(s) {overdue} missed the "
                         f"{self.hb_deadline:.1f}s heartbeat deadline")
        return "; ".join(parts) or None

    # ------------------------------ accounting ----------------------------

    def finalize_ledger(self, chunked, progress: np.ndarray) -> list[dict]:
        """Attach equations lost/recovered to terminal ledger entries.

        ``progress`` is the master's consumed-chunk count per worker; a
        terminal worker's recovered equations are the expanded-M rows of its
        consumed prefix, its lost equations the remaining nonempty rows.
        Only the *observed*-terminal kinds are annotated (the injected
        ``kill``/``pause`` that caused them would double-count).
        """
        for entry in self.ledger.entries:
            if entry["kind"] not in ("crash_detected", "drop_result",
                                     "deadline_missed"):
                continue
            w = entry["worker"]
            consumed = int(progress[w]) if w < len(progress) else 0
            recovered = sum(
                len(chunked.expanded_rows(w, c)) for c in range(consumed))
            total = sum(
                len(chunked.expanded_rows(w, c))
                for c in range(chunked.num_chunks))
            entry["equations_recovered"] = recovered
            entry["equations_lost"] = total - recovered
        return list(self.ledger.entries)


# --------------------------- job-multiplexed pool ---------------------------

def _mux_worker_main(worker, conn, sleep_per_chunk):
    """Persistent mux subprocess (spawn target; must stay module-level).

    Serves batch after batch over one duplex pipe.  Wire format:
    master -> worker ``("batch", epoch, items, jobdata)`` with ``items`` a
    fair ``[(jid, chunk)]`` schedule and ``jobdata[jid] = (row_chunks,
    A_blocks, B_blocks, n, q)``; ``("job_done", jid)`` cancels a job's
    not-yet-started chunks (the worker drains control messages before every
    item); ``("stop",)`` ends the process.  worker -> master ``("hello", w,
    pid)`` once, ``("chunk", w, epoch, jid, c, payload)`` per result in
    order, ``("fin", w, epoch)`` when its batch schedule is drained.  Pipe
    EOF without a fin is a crash, whatever the exit code says.
    """
    try:
        conn.send(("hello", worker, os.getpid()))
    except (BrokenPipeError, OSError):
        return
    done = set()
    try:
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                return
            if msg[0] == "stop":
                return
            if msg[0] == "job_done":
                done.add(msg[1])
                continue
            _, epoch, items, jobdata = msg
            for jid, c in items:
                while conn.poll():  # control messages preempt the schedule
                    m2 = conn.recv()
                    if m2[0] == "stop":
                        return
                    if m2[0] == "job_done":
                        done.add(m2[1])
                if jid in done:
                    continue
                row_chunks, A_blocks, B_blocks, n, q = jobdata[jid]
                if sleep_per_chunk:
                    time.sleep(sleep_per_chunk / q)
                payload = {}
                for r, chunks in row_chunks.items():
                    out = encode_blocks(chunks[c], A_blocks, B_blocks, n)
                    if out is not None:
                        payload[r * q + c] = out
                conn.send(("chunk", worker, epoch, jid, c, payload))
            conn.send(("fin", worker, epoch))
    except (BrokenPipeError, OSError):
        return  # master went away: nothing left to report to
    finally:
        conn.close()


class MuxProcPool:
    """``JobMux`` event source over persistent OS subprocess workers.

    The third mux transport after ``_MuxSimSource`` and ``_MuxLiveSource``:
    construct a ``JobMux``-compatible source whose workers are real
    processes spawned ONCE and reused batch after batch (pass the instance
    as ``JobMux(num_workers, source=pool)``).  Faults are real: a
    ``runtime.chaos`` plan
    SIGKILLs or throttles live pids (``kill.after_chunk`` counts the
    worker's per-job chunk index of the arrival that triggers it), crashes
    surface as pipe EOF and land in ``self.ledger``, and later batches
    simply stop scheduling the dead worker -- coded jobs keep decoding,
    uncoded jobs that needed it fail alone.  Hangs are covered by the batch
    ``timeout`` (this pool has no heartbeat thread; use ``ProcPool`` for
    deadline semantics on single jobs).
    """

    def __init__(self, num_workers: int, *,
                 straggler_sleep: dict[int, float] | None = None,
                 timeout: float = 60.0, plan=None):
        self.num_workers = int(num_workers)
        self.straggler_sleep = dict(straggler_sleep or {})
        self.timeout = float(timeout)
        self.ledger = FaultLedger()
        plan = FaultPlan.coerce(plan)
        for f in plan.faults:
            if f.worker >= self.num_workers:
                raise ValueError(f"fault {f.kind} targets worker {f.worker}, "
                                 f"pool has {self.num_workers}")
        self.injector = FaultInjector(plan, self.ledger)
        self._ctx = multiprocessing.get_context("spawn")
        self._conns: dict[int, object] = {}
        self._procs: dict[int, object] = {}
        self._pids: dict[int, int] = {}
        self._crashed: set[int] = set()
        self._epoch = 0

    def start(self) -> None:
        if self._procs:
            return
        self.ledger.t0 = time.perf_counter()
        for w in range(self.num_workers):
            master_end, worker_end = self._ctx.Pipe(duplex=True)
            proc = self._ctx.Process(
                target=_mux_worker_main,
                args=(w, worker_end, self.straggler_sleep.get(w, 0.0)),
                daemon=True, name=f"mux-proc-worker-{w}")
            proc.start()
            worker_end.close()
            self._conns[w] = master_end
            self._procs[w] = proc

    def close(self) -> None:
        self.injector.shutdown()
        for w, conn in list(self._conns.items()):
            if conn is not None:
                try:
                    conn.send(("stop",))
                except (BrokenPipeError, OSError):
                    pass
        deadline = time.perf_counter() + 5.0
        for w, proc in self._procs.items():
            proc.join(timeout=max(0.1, deadline - time.perf_counter()))
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=1.0)
            if self._conns.get(w) is not None:
                self._conns[w].close()
                self._conns[w] = None
        self._procs = {}

    def job_done(self, jid: int) -> None:
        for conn in self._conns.values():
            if conn is None:
                continue
            try:
                conn.send(("job_done", jid))
            except (BrokenPipeError, OSError):
                pass  # the recv loop will classify the EOF

    def submit(self, chunkeds, jobs):
        from repro.runtime.executor import _fair_worker_items

        self._epoch += 1
        jobrows = {}
        for jid, job in jobs.items():
            tasks_by_row = {t.worker: t for t in make_tasks(job.code.M)}
            jobrows[jid] = (job, tasks_by_row, chunkeds[jid].num_chunks)
        for w in range(self.num_workers):
            conn = self._conns.get(w)
            if conn is None:
                continue
            items = _fair_worker_items(chunkeds, w)
            jobdata = {}
            for jid in {jid for jid, _ in items}:
                job, tasks_by_row, q = jobrows[jid]
                row_chunks = {r: tasks_by_row[r].chunks(q)
                              for r in job.code.worker_rows[w]}
                jobdata[jid] = (row_chunks, job.A_blocks, job.B_blocks,
                                job.n, q)
            try:
                conn.send(("batch", self._epoch, items, jobdata))
            except (BrokenPipeError, OSError):
                self._sever(w, None)
        return self._events(self._epoch)

    def _sever(self, w: int, proc_join: float | None = 0.5) -> None:
        conn = self._conns.get(w)
        if conn is not None:
            conn.close()
        self._conns[w] = None
        if w not in self._crashed:
            self._crashed.add(w)
            proc = self._procs.get(w)
            if proc is not None and proc_join is not None:
                proc.join(timeout=proc_join)
            self.ledger.record(
                "crash_detected", w,
                exitcode=proc.exitcode if proc is not None else None)

    def _events(self, epoch: int):
        t0 = time.perf_counter()
        last_progress = t0
        fins: set[int] = set()
        while True:
            conns = {conn: w for w, conn in self._conns.items()
                     if conn is not None and w not in fins}
            if not conns:
                break
            ready = mp_connection.wait(list(conns), timeout=_POLL)
            for conn in ready:
                w = conns[conn]
                while self._conns.get(w) is not None and conn.poll():
                    try:
                        msg = conn.recv()
                    except (EOFError, OSError):
                        self._sever(w)
                        break
                    last_progress = time.perf_counter()
                    tag = msg[0]
                    if tag == "hello":
                        self._pids[w] = msg[2]
                        self.injector.on_spawn(w, msg[2])
                    elif tag == "chunk":
                        _, _, ep, jid, c, payload = msg
                        if ep != epoch:  # cancelled leftovers of a past batch
                            continue
                        if self.injector.should_drop(w, c):
                            continue
                        self.injector.on_result(w, c)
                        yield time.perf_counter() - t0, w, jid, c, payload
                    elif tag == "fin" and msg[2] == epoch:
                        fins.add(w)
            if time.perf_counter() - last_progress > self.timeout:
                raise _EventSourceDry(
                    f"no worker result within {self.timeout:.1f}s and the "
                    "collected chunks do not decode (hung or dead workers?)")
        if self._crashed:
            raise _EventSourceDry(
                f"worker process(es) {sorted(self._crashed)} crashed")


# ------------------------------- entry point --------------------------------

def run_proc_job(
    code: CodeInstance,
    A_blocks,
    B_blocks,
    n: int,
    straggler_sleep: dict[int, float] | None = None,
    num_chunks: int = 1,
    timeout: float = 60.0,
    plan=None,
    heartbeat_interval: float = 0.05,
    heartbeat_deadline: float = 2.0,
    respawn: bool = False,
) -> ExecutionReport:
    """``run_live_job`` with real OS subprocesses and (optionally) real
    faults.

    Mirrors ``run_live_job``'s signature and semantics -- same blocks, same
    chunk-granular protocol, same first-decodable-prefix stop rule -- plus:

    ``plan``      a ``runtime.chaos`` fault plan (or list of faults) the
                  injector executes against the live worker pids;
    ``heartbeat_interval`` / ``heartbeat_deadline``
                  workers beat every interval; a worker silent past the
                  deadline stops being waited on (its late arrivals still
                  count if they show up);
    ``respawn``   one-shot recovery: a crashed worker's remaining chunk
                  suffix is reassigned to a fresh process resuming at the
                  next in-order chunk.

    The report carries the full fault ledger and a populated
    ``decode_stats`` (arrivals, tracker rank, exact-test count, fault
    summary).  An unrecoverable fault set raises ``DecodingError`` naming
    the crashed/severed/overdue workers.

    Workers are spawn-started, so a script calling this from module scope
    needs the standard ``if __name__ == "__main__":`` guard (the child
    re-imports the caller's main module).
    """
    chunked = code.chunked(num_chunks)
    pool = ProcPool(
        code, num_chunks, A_blocks, B_blocks, n,
        straggler_sleep=straggler_sleep,
        heartbeat_interval=heartbeat_interval,
        heartbeat_deadline=heartbeat_deadline,
        respawn=respawn, plan=plan)
    t0 = pool.start()
    try:
        state = _consume_events(chunked, pool.events(timeout))
        compute_time = time.perf_counter() - t0
    finally:
        pool.shutdown()

    t1 = time.perf_counter()
    blocks = chunked.decode(state.pairs, state.results_by_row)
    decode_time = time.perf_counter() - t1

    ledger = pool.finalize_ledger(chunked, state.progress)
    return ExecutionReport(
        scheme=chunked.name,
        workers_used=int((state.progress > 0).sum()),
        num_workers=code.num_workers,
        sim_compute_time=compute_time,
        decode_wall_time=decode_time,
        total_time=compute_time + decode_time,
        decode_stats=state.decode_stats(faults=pool.ledger.summary()),
        blocks=blocks,
        num_chunks=num_chunks,
        chunks_used=len(state.pairs),
        fault_ledger=ledger,
    )
