"""LRU cache for worker tile packs (the block_sparse backend's host metadata).

``pack_worker_tiles`` is pure in its two inputs, both of which are reused
heavily by the runtime: a training/serving loop packs the same BlockELL
against the same plan on every step, and survivor-mask re-derivations
(``plan.with_survivors``) never change the pack at all -- it depends only on
``plan.cols``/``plan.weights``.  The cache key is identity of both objects
(``id(ell), id(plan)``): BlockELL holds mutable ndarrays, so value-hashing
would be both slow (it defeats the point of caching the pack) and unsound
under in-place mutation.  Keying on identity is safe because the cache entry
pins strong references to the keyed objects -- a live key id can never be
recycled while its entry is resident.

The runtime layer owns this cache (not core): core stays a pure library.
The consumer is ``repro.coded.CodedOp`` -- ``op.pack_for(ell)`` (and
therefore ``op.apply(..., a_sparse=ell)``) consults it keyed on the op's
BASE plan, so survivor rebinds of the same op share one pack.
"""

from __future__ import annotations

from collections import OrderedDict

from repro.core.coded_matmul import CodedMatmulPlan, WorkerTilePack, pack_worker_tiles
from repro.sparse.blocksparse import BlockELL

_MAX_ENTRIES = 16

# key -> (ell, plan, pack): the ell/plan refs pin the ids the key is built from
_cache: OrderedDict[tuple[int, int], tuple[BlockELL, CodedMatmulPlan, WorkerTilePack]]
_cache = OrderedDict()
_hits = 0
_misses = 0


def get_pack(ell: BlockELL, plan: CodedMatmulPlan) -> WorkerTilePack:
    """The pack for (ell, plan), computed at most once while both are alive."""
    global _hits, _misses
    key = (id(ell), id(plan))
    hit = _cache.get(key)
    if hit is not None:
        _cache.move_to_end(key)
        _hits += 1
        return hit[2]
    pack = pack_worker_tiles(ell, plan)
    _cache[key] = (ell, plan, pack)
    if len(_cache) > _MAX_ENTRIES:
        _cache.popitem(last=False)
    _misses += 1
    return pack


def cache_stats() -> dict:
    return {"entries": len(_cache), "hits": _hits, "misses": _misses}


def clear() -> None:
    global _hits, _misses
    _cache.clear()
    _hits = 0
    _misses = 0
