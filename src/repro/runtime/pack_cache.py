"""LRU cache for worker tile packs (the block_sparse backend's host metadata).

``pack_worker_tiles`` is pure in its two inputs, both of which are reused
heavily by the runtime: a training/serving loop packs the same BlockELL
against the same plan on every step, and survivor-mask re-derivations
(``plan.with_survivors``) never change the pack at all -- it depends only on
``plan.cols``/``plan.weights``.  The cache key is identity of both objects
(``id(ell), id(plan)``): BlockELL holds mutable ndarrays, so value-hashing
would be both slow (it defeats the point of caching the pack) and unsound
under in-place mutation.  Keying on identity is safe because the cache entry
pins strong references to the keyed objects -- a live key id can never be
recycled while its entry is resident.

The runtime layer owns this cache (not core): core stays a pure library.
The consumer is ``repro.coded.CodedOp`` -- ``op.pack_for(ell)`` (and
therefore ``op.apply(..., a_sparse=ell)``) consults it keyed on the op's
BASE plan, so survivor rebinds of the same op share one pack.  The cache is
a ``PackCache`` object with hit/miss/eviction counters; the module-level
functions operate on the process-wide default instance (``GLOBAL``), whose
``stats()`` snapshot rides along in ``ExecutionReport.decode_stats`` so
multi-job cache sharing is auditable from any report.
"""

from __future__ import annotations

from collections import OrderedDict

from repro.core.coded_matmul import CodedMatmulPlan, WorkerTilePack, pack_worker_tiles
from repro.sparse.blocksparse import BlockELL

_MAX_ENTRIES = 16


class PackCache:
    """Identity-keyed LRU of (BlockELL, plan, compute_dtype) -> WorkerTilePack."""

    def __init__(self, max_entries: int = _MAX_ENTRIES):
        self.max_entries = max_entries
        # key -> (ell, plan, pack): the refs pin the ids the key is built from
        self._cache: OrderedDict[
            tuple[int, int, str],
            tuple[BlockELL, CodedMatmulPlan, WorkerTilePack]]
        self._cache = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get_pack(self, ell: BlockELL, plan: CodedMatmulPlan,
                 compute_dtype: str = "float32") -> WorkerTilePack:
        """The pack for (ell, plan), computed at most once while both are
        alive.  compute_dtype is part of the key: an f32 pack and an int8
        pack of the same operands are different artifacts."""
        key = (id(ell), id(plan), compute_dtype)
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.hits += 1
            return hit[2]
        pack = pack_worker_tiles(ell, plan, compute_dtype=compute_dtype)
        self._cache[key] = (ell, plan, pack)
        if len(self._cache) > self.max_entries:
            self._cache.popitem(last=False)
            self.evictions += 1
        self.misses += 1
        return pack

    def stats(self) -> dict:
        return {"entries": len(self._cache), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions}

    def clear(self) -> None:
        self._cache.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0


#: the process-wide cache every ``CodedOp`` (and so every job) shares
GLOBAL = PackCache()


def get_pack(ell: BlockELL, plan: CodedMatmulPlan,
             compute_dtype: str = "float32") -> WorkerTilePack:
    return GLOBAL.get_pack(ell, plan, compute_dtype=compute_dtype)


def cache_stats() -> dict:
    return GLOBAL.stats()


def clear() -> None:
    GLOBAL.clear()
