"""Master/worker execution of a coded matrix-multiplication job.

Two modes:

* ``run_coded_job`` -- event-driven simulation.  Worker completion times are
  drawn from (nominal-cost x straggler-model); the master replays arrivals in
  time order, incrementally testing decodability, and decode time is measured
  for real on the actual data.  This is the reproducible mode used by the
  benchmark suite (the paper's Figs. 5-6 / Table III protocol: N workers, s
  slowed, master polls with Waitany until enough results arrive).

* ``run_live_job`` -- actually-concurrent execution on a thread pool with
  injected sleeps: workers compute real scipy.sparse block products and push
  to a queue; the master consumes (the MPI Isend/Irecv/Waitany analogue),
  stopping as soon as the collected rows are decodable.  Used by the
  straggler_sim example and the integration tests.

* ``run_device_job`` -- the SPMD device path: a thin timing wrapper over
  ``repro.coded.CodedOp`` (workers = devices, decode = one psum, or a
  psum_scatter with ``out_sharded=True``).  Backend dispatch, tile packing,
  the pack cache, and survivor rebinding are owned by the op; this layer
  only builds it, times the jitted apply, and wraps an ``ExecutionReport``
  -- the bridge from the host master/worker protocol above to the
  on-device execution the ROADMAP targets.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Sequence

import numpy as np
import scipy.sparse as sp

from repro.core.decoder import DecodingError
from repro.core.encoder import encode_blocks, CodedTask
from repro.core.schemes import CodeInstance


@dataclasses.dataclass
class ExecutionReport:
    scheme: str
    workers_used: int
    num_workers: int
    sim_compute_time: float       # simulated time until decodable set arrived
    decode_wall_time: float       # measured wall time of the decode
    total_time: float             # sim_compute_time + decode_wall_time
    decode_stats: dict
    blocks: list | None = None

    def summary(self) -> str:
        return (f"{self.scheme}: waited {self.workers_used}/{self.num_workers} workers, "
                f"compute {self.sim_compute_time:.4f}s + decode {self.decode_wall_time:.4f}s "
                f"= {self.total_time:.4f}s")


def _worker_results(code: CodeInstance, blocks_true: Sequence) -> dict[int, object]:
    """Exact per-row results from the generator matrix (simulation path).

    Cost note: the simulation charges compute time via code.cost_factor; the
    data itself is produced here once so decode operates on real blocks.
    """
    M = code.M
    out = {}
    for r in range(M.shape[0]):
        lo, hi = M.indptr[r], M.indptr[r + 1]
        acc = None
        for c, w in zip(M.indices[lo:hi], M.data[lo:hi]):
            term = blocks_true[c] * w
            acc = term if acc is None else acc + term
        if acc is None:
            first = blocks_true[0]
            acc = (sp.csr_matrix(first.shape) if sp.issparse(first)
                   else np.zeros_like(first))
        out[r] = acc
    return out


def run_coded_job(
    code: CodeInstance,
    blocks_true: Sequence,
    straggler: "StragglerModel",
    rng: np.random.Generator | None = None,
    unit_block_time: float = 1.0,
    check_every: int = 1,
    keep_blocks: bool = False,
) -> ExecutionReport:
    """Event-driven simulation of one job under a straggler realization."""
    from repro.runtime.straggler import StragglerModel  # noqa: F401 (doc type)

    rng = rng or np.random.default_rng(0)
    nominal = code.cost_factor * unit_block_time
    times = straggler.completion_times(nominal, rng)
    order = np.argsort(times)

    results_by_row = _worker_results(code, blocks_true)

    finished: list[int] = []
    decodable_at = None
    for rank_pos, w in enumerate(order):
        finished.append(int(w))
        if len(code.rows_of(finished)) < code.mn:
            continue
        if (rank_pos % check_every) == 0 or rank_pos == len(order) - 1:
            if code.can_decode(finished):
                decodable_at = times[w]
                break
    if decodable_at is None:
        # final full check (check_every may have skipped the last arrival)
        if code.can_decode(finished):
            decodable_at = times[order[-1]]
        else:
            raise DecodingError(f"{code.name}: not decodable even with all workers")

    t0 = time.perf_counter()
    blocks = code.decode(finished, results_by_row)
    decode_time = time.perf_counter() - t0

    return ExecutionReport(
        scheme=code.name,
        workers_used=len(finished),
        num_workers=code.num_workers,
        sim_compute_time=float(decodable_at),
        decode_wall_time=decode_time,
        total_time=float(decodable_at) + decode_time,
        decode_stats={},
        blocks=blocks if keep_blocks else None,
    )


def run_device_job(
    A,
    B,
    plan,
    mesh=None,
    axis_name: str = "model",
    backend: str = "dense_scan",
    survivors=None,
    repeats: int = 3,
    a_sparse=None,
    out_sharded: bool = False,
) -> ExecutionReport:
    """One coded matmul on a JAX mesh via the SPMD path (thin CodedOp wrapper).

    A, B: (s, r) / (s, t) arrays (numpy or jax).  ``plan`` is a
    ``repro.core.coded_matmul.CodedMatmulPlan``; ``mesh`` defaults to a 1-D
    mesh over every visible device (its axis size must equal
    ``plan.num_workers``).  All execution policy lives in
    ``repro.coded.CodedOp`` now: backend dispatch, BlockELL packing, the
    runtime pack cache (hit when a caller-supplied ``a_sparse`` recurs),
    and survivor rebinding.  This wrapper only builds the op, times its
    jitted apply, and wraps the result in an ``ExecutionReport``.  The
    decode is folded into the device program (one collective), so
    decode_wall_time is reported as 0 and the whole staged computation is
    timed as compute.
    """
    import jax
    import jax.numpy as jnp

    from repro.coded import CodedMatmulConfig, from_plan

    cfg = CodedMatmulConfig(backend=backend, axis_name=axis_name,
                            out_sharded=out_sharded)
    op = from_plan(cfg, plan).bind(mesh)
    if survivors is not None:
        op = op.with_survivors(survivors)

    kw = {}
    if op.needs_pack:
        # pack on host BEFORE staging: the tile pack is static metadata and
        # cannot be derived from a traced operand inside jit.  A caller-
        # supplied a_sparse goes through the op's pack cache (identity-keyed,
        # so recurring ells hit); a freshly built BlockELL bypasses it --
        # caching it would only pin dead entries.
        if a_sparse is not None:
            kw["pack"] = op.pack_for(a_sparse)
        else:
            from repro.sparse.blocksparse import dense_to_block_ell

            ell = dense_to_block_ell(np.asarray(A, dtype=np.float32),
                                     block_size=op.config.block_size)
            kw["pack"] = op.pack_for(ell, use_cache=False)
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    fn = jax.jit(lambda a, b: op.apply(a, b, **kw))
    fn(A, B).block_until_ready()  # compile outside the timed region
    times = []
    result = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        result = fn(A, B)
        result.block_until_ready()
        times.append(time.perf_counter() - t0)
    elapsed = float(np.median(times))

    used = (int(op.survivors.sum()) if op.survivors is not None
            else plan.num_workers)
    return ExecutionReport(
        scheme=f"spmd_{backend}",
        workers_used=used,
        num_workers=plan.num_workers,
        sim_compute_time=elapsed,
        decode_wall_time=0.0,
        total_time=elapsed,
        decode_stats={"backend": backend, "max_degree": plan.max_degree,
                      "on_device_decode": True, "out_sharded": out_sharded},
        blocks=[np.asarray(result)],
    )


def run_live_job(
    code: CodeInstance,
    A_blocks: Sequence,
    B_blocks: Sequence,
    n: int,
    straggler_sleep: dict[int, float] | None = None,
    num_threads: int = 4,
) -> ExecutionReport:
    """Concurrent execution with real block products and injected sleeps.

    Each worker computes its coded combination (real sparse matmuls) and
    pushes (worker, result) to the master's queue; slow workers sleep first.
    The master drains the queue and stops at the first decodable prefix --
    stragglers' results genuinely never get waited on.
    """
    straggler_sleep = straggler_sleep or {}
    q: queue.Queue = queue.Queue()
    stop = threading.Event()

    tasks = list(range(len(code.worker_rows)))

    def worker_fn(w: int):
        delay = straggler_sleep.get(w, 0.0)
        if delay:
            time.sleep(delay)
        if stop.is_set():
            return
        out = {}
        for r in code.worker_rows[w]:
            lo, hi = code.M.indptr[r], code.M.indptr[r + 1]
            task = CodedTask(worker=w, cols=code.M.indices[lo:hi],
                             weights=code.M.data[lo:hi])
            out[r] = encode_blocks(task, A_blocks, B_blocks, n)
        q.put((w, out))

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker_fn, args=(w,), daemon=True)
               for w in tasks]
    for t in threads:
        t.start()

    finished: list[int] = []
    results_by_row: dict[int, object] = {}
    while True:
        w, out = q.get(timeout=60.0)
        finished.append(w)
        results_by_row.update(out)
        if len(code.rows_of(finished)) >= code.mn and code.can_decode(finished):
            break
        if len(finished) == code.num_workers:
            raise DecodingError(f"{code.name}: exhausted workers, not decodable")
    compute_time = time.perf_counter() - t0
    stop.set()

    t1 = time.perf_counter()
    blocks = code.decode(finished, results_by_row)
    decode_time = time.perf_counter() - t1

    return ExecutionReport(
        scheme=code.name,
        workers_used=len(finished),
        num_workers=code.num_workers,
        sim_compute_time=compute_time,
        decode_wall_time=decode_time,
        total_time=compute_time + decode_time,
        decode_stats={},
        blocks=blocks,
    )
