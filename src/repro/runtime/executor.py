"""Master/worker execution of a coded matrix-multiplication job.

ONE master event loop (`_consume_events`, DESIGN.md section 8) consumes
``(time, worker, chunk, payload)`` arrivals from pluggable event sources and
stops at the first decodable chunk prefix.  Decodability is gated per event
by an incremental rank tracker (``core.decoder.IncrementalRankTracker``,
O(mn * rank) per arrival) and confirmed with the exact scheme test only when
the tracker first fills -- the old per-event ``matrix_rank`` recompute is
gone.  Tasks are chunk-granular (``CodeInstance.chunked(q)``): a straggler
that finished q' < q of its ordered sub-tasks still contributes q' usable
equations, the partial-straggler protocol of Das & Ramamoorthy
(arXiv 2012.06065 / 2109.12070).  ``num_chunks=1`` is the paper's atomic
protocol, same arrivals, same decode.

Four entry points share that loop or wrap the device path:

* ``run_coded_job`` -- event-driven simulation.  Chunk completion times are
  drawn from (per-chunk nominal work x straggler model); the master replays
  arrivals in time order, materializing worker results lazily (cost tracks
  events consumed, not N), and decode time is measured for real on the
  actual data.  The reproducible mode used by the benchmark suite (paper
  Figs. 5-6 / Table III protocol, plus the chunked sweep).

* ``run_live_job`` -- actually-concurrent execution on real threads with
  injected sleeps: workers compute scipy.sparse chunk products and push to
  a queue; the master consumes (the MPI Isend/Irecv/Waitany analogue)
  through the same event loop.  A worker that hangs past ``timeout``
  surfaces as a ``DecodingError`` naming the silent workers, never a bare
  ``queue.Empty``; a worker thread that *exits* early (exception, stop
  flag) posts a terminal sentinel so the master stops expecting its
  arrivals instead of burning the full timeout on a known-dead worker.

* ``runtime.procpool.run_proc_job`` -- the same protocol with workers as
  real OS subprocesses (spawn + pipe transport), so faults are real:
  workers can be SIGKILLed, SIGSTOPped, or throttled mid-chunk
  (``runtime.chaos``) and the master recovers from whatever chunk
  prefixes survived.  Its event source feeds this module's
  ``_consume_events`` unchanged -- one protocol, three transports.

* ``run_device_job`` -- the SPMD device path: a thin timing wrapper over
  ``repro.coded.CodedOp`` (workers = devices, decode = one psum, or a
  psum_scatter with ``out_sharded=True``).  ``survivors`` may be the usual
  (N,) liveness mask or an (N, q) per-chunk mask -- a device that completed
  only its first chunks contributes those rows to the decode instead of
  being zeroed wholesale.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Iterator, Sequence

import numpy as np
import scipy.sparse as sp

from repro.core.decoder import DecodingError, IncrementalRankTracker
from repro.core.encoder import encode_blocks, make_tasks
from repro.core.schemes import ChunkedCode, CodeInstance


@dataclasses.dataclass
class ExecutionReport:
    scheme: str
    workers_used: int
    num_workers: int
    sim_compute_time: float       # simulated time until decodable set arrived
    decode_wall_time: float       # measured wall time of the decode
    total_time: float             # sim_compute_time + decode_wall_time
    decode_stats: dict
    blocks: list | None = None
    num_chunks: int = 1           # sub-tasks per worker (1 = atomic protocol)
    chunks_used: int = 0          # chunk arrivals consumed before decoding
    #: chronological fault ledger (process runtime): one dict per observed or
    #: injected fault -- kind, worker, time, and for terminal faults the
    #: equations lost vs recovered.  Empty for the thread/sim/device paths.
    fault_ledger: list = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        chunks = (f" ({self.chunks_used} chunks, q={self.num_chunks})"
                  if self.num_chunks > 1 else "")
        faults = (f" [{len(self.fault_ledger)} fault events]"
                  if self.fault_ledger else "")
        return (f"{self.scheme}: waited {self.workers_used}/{self.num_workers} workers"
                f"{chunks}, "
                f"compute {self.sim_compute_time:.4f}s + decode {self.decode_wall_time:.4f}s "
                f"= {self.total_time:.4f}s{faults}")


# --------------------------- the master event loop ---------------------------

class _EventSourceDry(Exception):
    """An event source gave up early (e.g. live queue timeout); the master
    decides whether the collected chunks decode anyway."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


@dataclasses.dataclass
class _MasterState:
    """What the shared loop hands back: everything needed to decode."""

    pairs: list[tuple[int, int]]          # (worker, chunk) in arrival order
    progress: np.ndarray                  # (N,) chunks consumed per worker
    results_by_row: dict[int, object]     # expanded-M row id -> block payload
    stop_time: float                      # event time of the decisive arrival
    exact_checks: int = 0                 # scheme-exact decodability tests run
    tracker_rows: int = 0                 # rows folded into the rank tracker
    tracker_rank: int = 0                 # tracker rank at stop

    def decode_stats(self, faults: dict | None = None) -> dict:
        """The host-path ``ExecutionReport.decode_stats`` payload."""
        return {
            "arrivals_consumed": len(self.pairs),
            "tracker_rows": self.tracker_rows,
            "tracker_rank": self.tracker_rank,
            "exact_checks": self.exact_checks,
            "faults": faults or {},
        }


def _consume_events(
    chunked: ChunkedCode,
    events: Iterator[tuple[float, int, int, dict[int, object]]],
) -> _MasterState:
    """THE master loop: drain arrivals until the collected chunks decode.

    Simulation and live threads are just event sources feeding this --
    there is one protocol, not two.  Each event is
    ``(time, worker, chunk, payload)`` with ``payload`` mapping expanded-M
    row ids to blocks; chunks of one worker must arrive in order (ordered
    sub-task streams).  Per event the rank tracker folds in the new rows;
    the exact (scheme-specific) decodability test runs only once the
    tracker reports full rank -- and again per event after that for
    peel-decoded schemes, whose decodability is stricter than rank.
    """
    tracker = IncrementalRankTracker(chunked.mn)
    progress = np.zeros(chunked.num_workers, dtype=np.int64)
    results_by_row: dict[int, object] = {}
    pairs: list[tuple[int, int]] = []
    last_time = 0.0
    exact_checks = 0
    why = (f"{chunked.name}: not decodable even with all "
           f"{chunked.num_workers} workers' chunks")
    try:
        for t, w, c, payload in events:
            if c != progress[w]:
                raise ValueError(
                    f"worker {w} delivered chunk {c} out of order "
                    f"(expected {progress[w]}): sub-task streams are ordered")
            progress[w] += 1
            pairs.append((w, c))
            last_time = t
            for r, blk in payload.items():
                results_by_row[r] = blk
                tracker.add(np.asarray(chunked.M[r].todense()))
            if tracker.is_full:
                exact_checks += 1
                if chunked.can_decode(pairs):
                    return _MasterState(
                        pairs=pairs, progress=progress,
                        results_by_row=results_by_row, stop_time=t,
                        exact_checks=exact_checks,
                        tracker_rows=tracker.rows_seen,
                        tracker_rank=tracker.rank)
    except _EventSourceDry as dry:
        never = np.flatnonzero(progress == 0).tolist()
        stalled = np.flatnonzero(
            (progress > 0) & (progress < chunked.num_chunks)).tolist()
        why = (f"{chunked.name}: {dry.reason}; workers {never} never "
               f"reported" + (f", workers {stalled} stalled mid-stream"
                              if stalled else ""))
    # events exhausted (or the source dried up): the tracker is a float
    # gate, so give the exact test the last word before declaring failure
    exact_checks += 1
    if chunked.can_decode(pairs):
        return _MasterState(pairs=pairs, progress=progress,
                            results_by_row=results_by_row, stop_time=last_time,
                            exact_checks=exact_checks,
                            tracker_rows=tracker.rows_seen,
                            tracker_rank=tracker.rank)
    raise DecodingError(why)


# ------------------------------ event sources -------------------------------

def _chunk_result(chunked: ChunkedCode, row: int, blocks_true: Sequence):
    """Exact payload of one expanded-M row (simulation path), computed
    lazily at arrival time so simulation cost tracks events consumed."""
    M = chunked.M
    lo, hi = M.indptr[row], M.indptr[row + 1]
    acc = None
    for c, w in zip(M.indices[lo:hi], M.data[lo:hi]):
        term = blocks_true[c] * w
        acc = term if acc is None else acc + term
    if acc is None:  # empty chunk row (filtered upstream, but stay safe)
        first = blocks_true[0]
        acc = (sp.csr_matrix(first.shape) if sp.issparse(first)
               else np.zeros_like(first))
    return acc


def _sim_events(
    chunked: ChunkedCode,
    blocks_true: Sequence,
    times: np.ndarray,
) -> Iterator[tuple[float, int, int, dict[int, object]]]:
    """Arrivals in simulated-time order; payloads materialize on consume.

    ``times``: (N, q) chunk completion times (rows nondecreasing).  The
    stable flat argsort keeps each worker's chunks in order under ties.
    """
    q = chunked.num_chunks
    order = np.argsort(times, axis=None, kind="stable")
    for flat in order:
        w, c = divmod(int(flat), q)
        payload = {r: _chunk_result(chunked, r, blocks_true)
                   for r in chunked.expanded_rows(w, c)}
        yield float(times[w, c]), w, c, payload


def _live_events(
    q_: "queue.Queue",
    num_workers: int,
    num_chunks: int,
    timeout: float,
    t0: float,
) -> Iterator[tuple[float, int, int, dict[int, object]]]:
    """Arrivals drained from the worker threads' queue (wall-clock times).

    The source expects ``num_chunks`` arrivals per worker but *learns* of
    terminal worker failure: a worker thread that exits posts the sentinel
    ``(w, None, None)``, which zeroes its outstanding count -- so a
    known-dead worker costs nothing once everyone else has reported,
    instead of a full ``timeout`` wait per missing chunk.  A dry queue past
    ``timeout`` means some worker hung without exiting: signal the master
    loop (which names the silent/stalled workers in a ``DecodingError``
    after the exact decodability test gets the last word) instead of
    leaking ``queue.Empty`` to the caller.
    """
    outstanding = np.full(num_workers, num_chunks, dtype=np.int64)
    exited_early: list[int] = []
    while int(outstanding.sum()) > 0:
        try:
            w, c, payload = q_.get(timeout=timeout)
        except queue.Empty:
            raise _EventSourceDry(
                f"no worker result within {timeout:.1f}s and the collected "
                "chunks do not decode (hung or dead workers?)") from None
        if c is None:  # terminal sentinel: worker w will deliver nothing more
            if outstanding[w] > 0:
                exited_early.append(int(w))
                outstanding[w] = 0
            continue
        outstanding[w] -= 1
        yield time.perf_counter() - t0, w, c, payload
    if exited_early:
        raise _EventSourceDry(
            f"worker thread(s) {sorted(set(exited_early))} exited before "
            "delivering all chunks")


# ------------------------------- entry points -------------------------------

def run_coded_job(
    code: CodeInstance,
    blocks_true: Sequence,
    straggler: "StragglerModel",
    rng: np.random.Generator | None = None,
    unit_block_time: float = 1.0,
    check_every: int = 1,
    keep_blocks: bool = False,
    num_chunks: int = 1,
) -> ExecutionReport:
    """Event-driven simulation of one job under a straggler realization.

    ``num_chunks`` > 1 runs the chunk-granular protocol: each worker's task
    splits into that many ordered sub-tasks and the master decodes from the
    first decodable chunk prefix -- at equal total work, never later than
    the atomic run (the atomic arrival set is a subset of the chunked one).
    ``check_every`` is retained for API compatibility; the incremental rank
    tracker already makes the per-event check cheap, so it is ignored.
    """
    del check_every  # superseded by the incremental rank tracker
    from repro.runtime.straggler import StragglerModel  # noqa: F401 (doc type)

    rng = rng or np.random.default_rng(0)
    chunked = code.chunked(num_chunks)
    work = chunked.chunk_work() * unit_block_time
    times = straggler.chunk_completion_times(work, rng)

    state = _consume_events(chunked, _sim_events(chunked, blocks_true, times))

    t0 = time.perf_counter()
    blocks = chunked.decode(state.pairs, state.results_by_row)
    decode_time = time.perf_counter() - t0

    return ExecutionReport(
        scheme=chunked.name,
        workers_used=int((state.progress > 0).sum()),
        num_workers=code.num_workers,
        sim_compute_time=float(state.stop_time),
        decode_wall_time=decode_time,
        total_time=float(state.stop_time) + decode_time,
        decode_stats=state.decode_stats(),
        blocks=blocks if keep_blocks else None,
        num_chunks=num_chunks,
        chunks_used=len(state.pairs),
    )


def run_live_job(
    code: CodeInstance,
    A_blocks: Sequence,
    B_blocks: Sequence,
    n: int,
    straggler_sleep: dict[int, float] | None = None,
    num_threads: int = 4,
    num_chunks: int = 1,
    timeout: float = 60.0,
) -> ExecutionReport:
    """Concurrent execution with real block products and injected sleeps.

    Each worker computes its coded combination chunk by chunk (real sparse
    matmuls; an injected sleep is spread evenly across the chunks) and
    pushes ``(worker, chunk, payload)`` to the master's queue; the master
    consumes through the shared event loop and stops at the first decodable
    chunk prefix -- a straggler's finished chunks count, its unfinished
    ones genuinely never get waited on.

    Workers observe the stop flag before *every* matmul and sleep
    interruptibly (``stop.wait``), and the master joins them with a bounded
    timeout before returning -- an early decode does not leak threads that
    keep computing (or sleeping) the remaining chunks in the background.
    A worker that raises exits through its terminal sentinel, so the master
    stops expecting it instead of waiting out the timeout.
    """
    del num_threads  # one thread per worker, as the protocol prescribes
    straggler_sleep = straggler_sleep or {}
    chunked = code.chunked(num_chunks)
    q_: queue.Queue = queue.Queue()
    stop = threading.Event()

    tasks_by_row = {t.worker: t for t in make_tasks(code.M)}  # row id -> task

    def worker_fn(w: int):
        delay = straggler_sleep.get(w, 0.0) / num_chunks
        row_chunks = {r: tasks_by_row[r].chunks(num_chunks)
                      for r in code.worker_rows[w]}
        try:
            for c in range(num_chunks):
                if delay and stop.wait(delay):  # interruptible sleep
                    return
                payload = {}
                for r, chunks in row_chunks.items():
                    if stop.is_set():
                        return
                    out = encode_blocks(chunks[c], A_blocks, B_blocks, n)
                    if out is not None:
                        payload[r * num_chunks + c] = out
                if stop.is_set():
                    return
                q_.put((w, c, payload))
        except Exception:
            pass  # the sentinel below tells the master w is terminal
        finally:
            q_.put((w, None, None))

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker_fn, args=(w,), daemon=True,
                                name=f"live-worker-{w}")
               for w in range(code.num_workers)]
    for t in threads:
        t.start()

    try:
        state = _consume_events(
            chunked, _live_events(q_, code.num_workers, num_chunks,
                                  timeout, t0))
    finally:
        stop.set()
        # bounded join: stop-aware workers exit after at most one more block
        # matmul (sleeps wake immediately on stop); the daemon flag stays as
        # the backstop for a truly wedged one
        join_deadline = time.perf_counter() + 5.0
        for t in threads:
            t.join(timeout=max(0.0, join_deadline - time.perf_counter()))
    compute_time = time.perf_counter() - t0

    t1 = time.perf_counter()
    blocks = chunked.decode(state.pairs, state.results_by_row)
    decode_time = time.perf_counter() - t1

    return ExecutionReport(
        scheme=chunked.name,
        workers_used=int((state.progress > 0).sum()),
        num_workers=code.num_workers,
        sim_compute_time=compute_time,
        decode_wall_time=decode_time,
        total_time=compute_time + decode_time,
        decode_stats=state.decode_stats(),
        blocks=blocks,
        num_chunks=num_chunks,
        chunks_used=len(state.pairs),
    )


def run_device_job(
    A,
    B,
    plan,
    mesh=None,
    axis_name: str = "model",
    backend: str = "dense_scan",
    survivors=None,
    repeats: int = 3,
    a_sparse=None,
    out_sharded: bool = False,
) -> ExecutionReport:
    """One coded matmul on a JAX mesh via the SPMD path (thin CodedOp wrapper).

    A, B: (s, r) / (s, t) arrays (numpy or jax).  ``plan`` is a
    ``repro.core.coded_matmul.CodedMatmulPlan``; ``mesh`` defaults to a 1-D
    mesh over every visible device (its axis size must equal
    ``plan.num_workers``).  All execution policy lives in
    ``repro.coded.CodedOp``: backend dispatch, BlockELL packing, the runtime
    pack cache (hit when a caller-supplied ``a_sparse`` recurs), and
    survivor rebinding -- ``survivors`` may be an (N,) liveness mask or an
    (N, q) per-chunk completion mask (partial stragglers contribute their
    finished prefix rows).  This wrapper only builds the op, times its
    jitted apply, and wraps the result in an ``ExecutionReport``.  The
    decode is folded into the device program (one collective), so
    decode_wall_time is reported as 0 and the whole staged computation is
    timed as compute.
    """
    import jax
    import jax.numpy as jnp

    from repro.coded import CodedMatmulConfig, from_plan

    cfg = CodedMatmulConfig(backend=backend, axis_name=axis_name,
                            out_sharded=out_sharded)
    op = from_plan(cfg, plan).bind(mesh)
    if survivors is not None:
        op = op.with_survivors(survivors)

    kw = {}
    if op.needs_pack:
        # pack on host BEFORE staging: the tile pack is static metadata and
        # cannot be derived from a traced operand inside jit.  A caller-
        # supplied a_sparse goes through the op's pack cache (identity-keyed,
        # so recurring ells hit); a freshly built BlockELL bypasses it --
        # caching it would only pin dead entries.
        if a_sparse is not None:
            kw["pack"] = op.pack_for(a_sparse)
        else:
            from repro.sparse.blocksparse import dense_to_block_ell

            ell = dense_to_block_ell(np.asarray(A, dtype=np.float32),
                                     block_size=op.config.block_size)
            kw["pack"] = op.pack_for(ell, use_cache=False)
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    fn = jax.jit(lambda a, b: op.apply(a, b, **kw))
    fn(A, B).block_until_ready()  # compile outside the timed region
    times = []
    result = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        result = fn(A, B)
        result.block_until_ready()
        times.append(time.perf_counter() - t0)
    elapsed = float(np.median(times))

    used = (int(op.survivors.sum()) if op.survivors is not None
            else plan.num_workers)
    return ExecutionReport(
        scheme=f"spmd_{backend}",
        workers_used=used,
        num_workers=plan.num_workers,
        sim_compute_time=elapsed,
        decode_wall_time=0.0,
        total_time=elapsed,
        decode_stats={"backend": backend, "max_degree": plan.max_degree,
                      "on_device_decode": True, "out_sharded": out_sharded},
        blocks=[np.asarray(result)],
    )
