"""Master/worker execution of a coded matrix-multiplication job.

ONE master event loop (`_consume_events`, DESIGN.md section 8) consumes
``(time, worker, chunk, payload)`` arrivals from pluggable event sources and
stops at the first decodable chunk prefix.  Decodability is gated per event
by an incremental rank tracker (``core.decoder.IncrementalRankTracker``,
O(mn * rank) per arrival) and confirmed with the exact scheme test only when
the tracker first fills -- the old per-event ``matrix_rank`` recompute is
gone.  Tasks are chunk-granular (``CodeInstance.chunked(q)``): a straggler
that finished q' < q of its ordered sub-tasks still contributes q' usable
equations, the partial-straggler protocol of Das & Ramamoorthy
(arXiv 2012.06065 / 2109.12070).  ``num_chunks=1`` is the paper's atomic
protocol, same arrivals, same decode.

Four entry points share that loop or wrap the device path:

* ``run_coded_job`` -- event-driven simulation.  Chunk completion times are
  drawn from (per-chunk nominal work x straggler model); the master replays
  arrivals in time order, materializing worker results lazily (cost tracks
  events consumed, not N), and decode time is measured for real on the
  actual data.  The reproducible mode used by the benchmark suite (paper
  Figs. 5-6 / Table III protocol, plus the chunked sweep).

* ``run_live_job`` -- actually-concurrent execution on real threads with
  injected sleeps: workers compute scipy.sparse chunk products and push to
  a queue; the master consumes (the MPI Isend/Irecv/Waitany analogue)
  through the same event loop.  A worker that hangs past ``timeout``
  surfaces as a ``DecodingError`` naming the silent workers, never a bare
  ``queue.Empty``; a worker thread that *exits* early (exception, stop
  flag) posts a terminal sentinel so the master stops expecting its
  arrivals instead of burning the full timeout on a known-dead worker.

* ``runtime.procpool.run_proc_job`` -- the same protocol with workers as
  real OS subprocesses (spawn + pipe transport), so faults are real:
  workers can be SIGKILLed, SIGSTOPped, or throttled mid-chunk
  (``runtime.chaos``) and the master recovers from whatever chunk
  prefixes survived.  Its event source feeds this module's
  ``_consume_events`` unchanged -- one protocol, three transports.

* ``run_device_job`` -- the SPMD device path: a thin timing wrapper over
  ``repro.coded.CodedOp`` (workers = devices, decode = one psum, or a
  psum_scatter with ``out_sharded=True``).  ``survivors`` may be the usual
  (N,) liveness mask or an (N, q) per-chunk mask -- a device that completed
  only its first chunks contributes those rows to the decode instead of
  being zeroed wholesale.
"""

from __future__ import annotations

import dataclasses
import heapq
import queue
import threading
import time
from typing import Iterator, Sequence

import numpy as np
import scipy.sparse as sp

from repro.core.decoder import DecodingError, IncrementalRankTracker
from repro.core.encoder import encode_blocks, make_tasks
from repro.core.schemes import ChunkedCode, CodeInstance


@dataclasses.dataclass
class ExecutionReport:
    scheme: str
    workers_used: int
    num_workers: int
    sim_compute_time: float       # simulated time until decodable set arrived
    decode_wall_time: float       # measured wall time of the decode
    total_time: float             # sim_compute_time + decode_wall_time
    decode_stats: dict
    blocks: list | None = None
    num_chunks: int = 1           # sub-tasks per worker (1 = atomic protocol)
    chunks_used: int = 0          # chunk arrivals consumed before decoding
    #: chronological fault ledger (process runtime): one dict per observed or
    #: injected fault -- kind, worker, time, and for terminal faults the
    #: equations lost vs recovered.  Empty for the thread/sim/device paths.
    fault_ledger: list = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        chunks = (f" ({self.chunks_used} chunks, q={self.num_chunks})"
                  if self.num_chunks > 1 else "")
        faults = (f" [{len(self.fault_ledger)} fault events]"
                  if self.fault_ledger else "")
        return (f"{self.scheme}: waited {self.workers_used}/{self.num_workers} workers"
                f"{chunks}, "
                f"compute {self.sim_compute_time:.4f}s + decode {self.decode_wall_time:.4f}s "
                f"= {self.total_time:.4f}s{faults}")


# --------------------------- the master event loop ---------------------------

class _EventSourceDry(Exception):
    """An event source gave up early (e.g. live queue timeout); the master
    decides whether the collected chunks decode anyway."""

    def __init__(self, reason: str):
        super().__init__(reason)
        self.reason = reason


@dataclasses.dataclass
class _MasterState:
    """What the shared loop hands back: everything needed to decode."""

    pairs: list[tuple[int, int]]          # (worker, chunk) in arrival order
    progress: np.ndarray                  # (N,) chunks consumed per worker
    results_by_row: dict[int, object]     # expanded-M row id -> block payload
    stop_time: float                      # event time of the decisive arrival
    exact_checks: int = 0                 # scheme-exact decodability tests run
    tracker_rows: int = 0                 # rows folded into the rank tracker
    tracker_rank: int = 0                 # tracker rank at stop

    def decode_stats(self, faults: dict | None = None) -> dict:
        """The host-path ``ExecutionReport.decode_stats`` payload."""
        return {
            "arrivals_consumed": len(self.pairs),
            "tracker_rows": self.tracker_rows,
            "tracker_rank": self.tracker_rank,
            "exact_checks": self.exact_checks,
            "faults": faults or {},
        }


@dataclasses.dataclass
class _JobProgress:
    """Per-job master state while the job is still in flight."""

    chunked: ChunkedCode
    tracker: IncrementalRankTracker
    progress: np.ndarray
    results_by_row: dict[int, object]
    pairs: list[tuple[int, int]]
    last_time: float = 0.0
    exact_checks: int = 0

    @classmethod
    def fresh(cls, chunked: ChunkedCode) -> "_JobProgress":
        return cls(chunked=chunked,
                   tracker=IncrementalRankTracker(chunked.mn),
                   progress=np.zeros(chunked.num_workers, dtype=np.int64),
                   results_by_row={}, pairs=[])

    def to_state(self, stop_time: float) -> _MasterState:
        return _MasterState(
            pairs=self.pairs, progress=self.progress,
            results_by_row=self.results_by_row, stop_time=stop_time,
            exact_checks=self.exact_checks,
            tracker_rows=self.tracker.rows_seen,
            tracker_rank=self.tracker.rank)


def _consume_mux_events(
    jobs: dict[int, ChunkedCode],
    events: Iterator[tuple[float, int, int, int, dict[int, object]]],
    job_done=None,
) -> tuple[dict[int, _MasterState], dict[int, str]]:
    """THE master loop, job-multiplexed: many jobs, one arrival stream.

    Each event is ``(time, worker, job, chunk, payload)`` with ``payload``
    mapping expanded-M row ids (of that job's code) to blocks; chunks of
    one (worker, job) stream must arrive in order.  Per event, that job's
    rank tracker folds in the new rows; the exact (scheme-specific)
    decodability test runs only once its tracker reports full rank.  A job
    that decodes stops consuming immediately (first-decodable-prefix early
    stop, per job) and ``job_done(jid)`` tells the source to cancel its
    not-yet-started chunks -- other jobs keep draining.  Arrivals for
    finished or unknown jobs (late chunks of a cancelled job, leftovers of
    a previous batch on a persistent pool) are skipped, not errors.

    Returns ``(states, failures)``: decodable jobs' ``_MasterState`` and,
    for jobs that never became decodable, the reason string -- one bad job
    (say, an uncoded job whose worker died) cannot fail the batch.
    """
    live = {jid: _JobProgress.fresh(chunked) for jid, chunked in jobs.items()}
    states: dict[int, _MasterState] = {}
    failures: dict[int, str] = {}
    dry_reason: str | None = None
    try:
        for t, w, jid, c, payload in events:
            jp = live.get(jid)
            if jp is None:  # finished job's late chunk / stale batch leftover
                continue
            if c != jp.progress[w]:
                raise ValueError(
                    f"worker {w} delivered chunk {c} out of order "
                    f"(expected {jp.progress[w]}): sub-task streams are ordered")
            jp.progress[w] += 1
            jp.pairs.append((w, c))
            jp.last_time = t
            for r, blk in payload.items():
                jp.results_by_row[r] = blk
                jp.tracker.add(np.asarray(jp.chunked.M[r].todense()))
            if jp.tracker.is_full:
                jp.exact_checks += 1
                if jp.chunked.can_decode(jp.pairs):
                    states[jid] = jp.to_state(stop_time=t)
                    del live[jid]
                    if job_done is not None:
                        job_done(jid)
                    if not live:
                        break
    except _EventSourceDry as dry:
        dry_reason = dry.reason
    # events exhausted (or the source dried up): the tracker is a float
    # gate, so give the exact test the last word before declaring failure
    for jid, jp in live.items():
        jp.exact_checks += 1
        if jp.chunked.can_decode(jp.pairs):
            states[jid] = jp.to_state(stop_time=jp.last_time)
            continue
        if dry_reason is None:
            failures[jid] = (f"{jp.chunked.name}: not decodable even with all "
                             f"{jp.chunked.num_workers} workers' chunks")
        else:
            never = np.flatnonzero(jp.progress == 0).tolist()
            stalled = np.flatnonzero(
                (jp.progress > 0)
                & (jp.progress < jp.chunked.num_chunks)).tolist()
            failures[jid] = (
                f"{jp.chunked.name}: {dry_reason}; workers {never} never "
                f"reported" + (f", workers {stalled} stalled mid-stream"
                               if stalled else ""))
    return states, failures


def _consume_events(
    chunked: ChunkedCode,
    events: Iterator[tuple[float, int, int, dict[int, object]]],
) -> _MasterState:
    """Single-job master loop: the one-job view of ``_consume_mux_events``.

    Simulation, live threads, and subprocess pools are just event sources
    feeding this -- there is one protocol, not two.  Each event is
    ``(time, worker, chunk, payload)``; see ``_consume_mux_events`` for the
    loop's semantics (rank-tracker gating, exact-test last word).  Raises
    ``DecodingError`` with the job's failure reason when the collected
    chunks never decode.
    """
    def tagged():
        for t, w, c, payload in events:
            yield t, w, 0, c, payload

    states, failures = _consume_mux_events({0: chunked}, tagged())
    if 0 in states:
        return states[0]
    raise DecodingError(failures[0])


# ------------------------------ event sources -------------------------------

def _chunk_result(chunked: ChunkedCode, row: int, blocks_true: Sequence):
    """Exact payload of one expanded-M row (simulation path), computed
    lazily at arrival time so simulation cost tracks events consumed."""
    M = chunked.M
    lo, hi = M.indptr[row], M.indptr[row + 1]
    acc = None
    for c, w in zip(M.indices[lo:hi], M.data[lo:hi]):
        term = blocks_true[c] * w
        acc = term if acc is None else acc + term
    if acc is None:  # empty chunk row (filtered upstream, but stay safe)
        first = blocks_true[0]
        acc = (sp.csr_matrix(first.shape) if sp.issparse(first)
               else np.zeros_like(first))
    return acc


def _sim_events(
    chunked: ChunkedCode,
    blocks_true: Sequence,
    times: np.ndarray,
) -> Iterator[tuple[float, int, int, dict[int, object]]]:
    """Arrivals in simulated-time order; payloads materialize on consume.

    ``times``: (N, q) chunk completion times (rows nondecreasing).  The
    stable flat argsort keeps each worker's chunks in order under ties.
    """
    q = chunked.num_chunks
    order = np.argsort(times, axis=None, kind="stable")
    for flat in order:
        w, c = divmod(int(flat), q)
        payload = {r: _chunk_result(chunked, r, blocks_true)
                   for r in chunked.expanded_rows(w, c)}
        yield float(times[w, c]), w, c, payload


def _live_events(
    q_: "queue.Queue",
    num_workers: int,
    num_chunks: int,
    timeout: float,
    t0: float,
) -> Iterator[tuple[float, int, int, dict[int, object]]]:
    """Arrivals drained from the worker threads' queue (wall-clock times).

    The source expects ``num_chunks`` arrivals per worker but *learns* of
    terminal worker failure: a worker thread that exits posts the sentinel
    ``(w, None, None)``, which zeroes its outstanding count -- so a
    known-dead worker costs nothing once everyone else has reported,
    instead of a full ``timeout`` wait per missing chunk.  A dry queue past
    ``timeout`` means some worker hung without exiting: signal the master
    loop (which names the silent/stalled workers in a ``DecodingError``
    after the exact decodability test gets the last word) instead of
    leaking ``queue.Empty`` to the caller.
    """
    outstanding = np.full(num_workers, num_chunks, dtype=np.int64)
    exited_early: list[int] = []
    while int(outstanding.sum()) > 0:
        try:
            w, c, payload = q_.get(timeout=timeout)
        except queue.Empty:
            raise _EventSourceDry(
                f"no worker result within {timeout:.1f}s and the collected "
                "chunks do not decode (hung or dead workers?)") from None
        if c is None:  # terminal sentinel: worker w will deliver nothing more
            if outstanding[w] > 0:
                exited_early.append(int(w))
                outstanding[w] = 0
            continue
        outstanding[w] -= 1
        yield time.perf_counter() - t0, w, c, payload
    if exited_early:
        raise _EventSourceDry(
            f"worker thread(s) {sorted(set(exited_early))} exited before "
            "delivering all chunks")


# ------------------------------ job multiplexer -----------------------------

@dataclasses.dataclass
class MuxJob:
    """One coded matmul job submitted to a ``JobMux`` pool.

    ``A_blocks``/``B_blocks`` are the column blocks of A and B (the job is
    C = A^T B over an (m, n) block grid, exactly as in ``run_live_job``);
    ``code.num_workers`` may be <= the pool size -- the job runs on the
    pool's first ``num_workers`` workers and leaves the rest to other jobs.
    ``tag`` is the caller's correlation key (e.g. a request id) and is
    echoed on the ``MuxResult``.
    """

    code: CodeInstance
    A_blocks: Sequence
    B_blocks: Sequence
    n: int
    num_chunks: int = 1
    tag: object = None


@dataclasses.dataclass
class MuxResult:
    """Outcome of one ``MuxJob``: a per-job report or a failure reason."""

    tag: object
    report: ExecutionReport | None
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.error is None

    @property
    def blocks(self):
        return self.report.blocks if self.report is not None else None


class _LazyTrueBlocks:
    """``blocks_true[i*n+j] = A_i^T B_j``, materialized on first touch so
    simulation cost tracks blocks actually referenced by consumed events."""

    def __init__(self, A_blocks: Sequence, B_blocks: Sequence, n: int):
        self._A, self._B, self._n = A_blocks, B_blocks, n
        self._cache: dict[int, object] = {}

    def __len__(self) -> int:
        return len(self._A) * self._n

    def __getitem__(self, k: int):
        out = self._cache.get(k)
        if out is None:
            i, j = divmod(k, self._n)
            out = self._cache[k] = self._A[i].T @ self._B[j]
        return out


def _fair_worker_items(
    chunkeds: dict[int, ChunkedCode], worker: int,
) -> list[tuple[int, int]]:
    """Chunk-major round-robin schedule for one worker: chunk 0 of every
    job (in submission order), then chunk 1 of every job, ...  No job's
    second chunk is computed before every job got its first -- the fairness
    policy that keeps one huge job from starving small ones."""
    jids = [jid for jid, ch in chunkeds.items() if worker < ch.num_workers]
    if not jids:
        return []
    maxq = max(chunkeds[jid].num_chunks for jid in jids)
    return [(jid, c) for c in range(maxq) for jid in jids
            if c < chunkeds[jid].num_chunks]


class _MuxSimSource:
    """Discrete-event simulation of one worker pool serving many jobs.

    Each worker is a rate-r server draining its fair chunk-major item queue
    in order; the straggler realization (one draw at pool construction, so
    the same worker stays slow across batches) sets the rates.  A job the
    master finished is cancelled: its not-yet-started items are skipped for
    free, its in-flight items complete (the worker already spent that time)
    and arrive as discarded late chunks.
    """

    def __init__(self, num_workers: int, straggler=None,
                 rng: np.random.Generator | None = None,
                 unit_block_time: float = 1.0,
                 dead_workers: Sequence[int] = ()):
        rng = rng or np.random.default_rng(0)
        base = np.ones(num_workers, dtype=np.float64)
        times = (straggler.completion_times(base, rng)
                 if straggler is not None else base)
        self.rates = 1.0 / np.asarray(times, dtype=np.float64)
        self.rates[list(dead_workers)] = 0.0
        self.num_workers = num_workers
        self.unit_block_time = unit_block_time
        self._done: set[int] = set()

    def start(self) -> None:
        pass

    def close(self) -> None:
        pass

    def job_done(self, jid: int) -> None:
        self._done.add(jid)

    def submit(self, chunkeds: dict[int, ChunkedCode],
               jobs: dict[int, MuxJob]):
        truth = {jid: _LazyTrueBlocks(j.A_blocks, j.B_blocks, j.n)
                 for jid, j in jobs.items()}
        work = {jid: ch.chunk_work() * self.unit_block_time
                for jid, ch in chunkeds.items()}
        return self._events(chunkeds, truth, work)

    def _events(self, chunkeds, truth, work):
        items = {w: _fair_worker_items(chunkeds, w)
                 for w in range(self.num_workers) if self.rates[w] > 0}
        heap: list[tuple[float, int, int, int, int]] = []
        ptr = {w: 0 for w in items}
        clock = {w: 0.0 for w in items}
        seq = 0

        def schedule(w: int) -> None:
            nonlocal seq
            while ptr[w] < len(items[w]):
                jid, c = items[w][ptr[w]]
                ptr[w] += 1
                if jid in self._done:  # cancelled before start: free skip
                    continue
                clock[w] += work[jid][w, c] / self.rates[w]
                heapq.heappush(heap, (clock[w], seq, w, jid, c))
                seq += 1
                return

        for w in items:
            schedule(w)
        while heap:
            t, _, w, jid, c = heapq.heappop(heap)
            if jid not in self._done:  # in-flight at cancel -> discard late
                ch = chunkeds[jid]
                payload = {r: _chunk_result(ch, r, truth[jid])
                           for r in ch.expanded_rows(w, c)}
                yield t, w, jid, c, payload
            schedule(w)


class _MuxLiveSource:
    """One persistent pool of worker threads serving batch after batch.

    Threads are spawned once (``start``) and park on a condition variable
    between batches; ``submit`` publishes a new epoch with per-worker fair
    item queues.  Workers check the shared done-set before every item, so a
    job the master finished stops costing compute mid-batch.  Workers in
    ``dead_workers`` are never spawned -- the pool-level analogue of a
    worker killed at t=0 -- and the batch's event stream ends by naming
    them, so per-job failures report who never showed up.
    """

    def __init__(self, num_workers: int,
                 straggler_sleep: dict[int, float] | None = None,
                 dead_workers: Sequence[int] = (),
                 timeout: float = 60.0):
        self.num_workers = num_workers
        self.straggler_sleep = straggler_sleep or {}
        self.dead = sorted(set(int(w) for w in dead_workers))
        self.timeout = timeout
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._cv = threading.Condition()
        self._epoch = 0
        self._batch: tuple[dict, dict] | None = None  # (items_by_worker, jobdata)
        self._done: set[int] = set()
        self._threads: list[threading.Thread] = []

    def start(self) -> None:
        if self._threads:
            return
        self._threads = [
            threading.Thread(target=self._worker_fn, args=(w,), daemon=True,
                             name=f"mux-worker-{w}")
            for w in range(self.num_workers) if w not in self.dead]
        for t in self._threads:
            t.start()

    def close(self) -> None:
        self._stop.set()
        with self._cv:
            self._cv.notify_all()
        join_deadline = time.perf_counter() + 5.0
        for t in self._threads:
            t.join(timeout=max(0.0, join_deadline - time.perf_counter()))
        self._threads = []

    def job_done(self, jid: int) -> None:
        self._done.add(jid)

    def submit(self, chunkeds: dict[int, ChunkedCode],
               jobs: dict[int, MuxJob]):
        items = {w: _fair_worker_items(chunkeds, w)
                 for w in range(self.num_workers)}
        jobdata = {}
        for jid, job in jobs.items():
            tasks_by_row = {t.worker: t for t in make_tasks(job.code.M)}
            jobdata[jid] = (job, tasks_by_row, chunkeds[jid].num_chunks)
        with self._cv:
            self._epoch += 1
            self._batch = (items, jobdata)
            epoch = self._epoch
            self._cv.notify_all()
        return self._events(epoch)

    def _worker_fn(self, w: int) -> None:
        last_seen = 0
        while True:
            with self._cv:
                self._cv.wait_for(
                    lambda: self._stop.is_set() or self._epoch > last_seen)
                if self._stop.is_set():
                    return
                last_seen = self._epoch
                items, jobdata = self._batch
            my_items = items.get(w, [])
            row_chunks: dict[int, dict] = {}  # jid -> {row: chunks}
            try:
                for jid, c in my_items:
                    if self._stop.is_set():
                        return
                    if jid in self._done:
                        continue
                    job, tasks_by_row, q = jobdata[jid]
                    if jid not in row_chunks:
                        row_chunks[jid] = {r: tasks_by_row[r].chunks(q)
                                           for r in job.code.worker_rows[w]}
                    delay = self.straggler_sleep.get(w, 0.0) / q
                    if delay and self._stop.wait(delay):  # interruptible
                        return
                    payload = {}
                    for r, chunks in row_chunks[jid].items():
                        out = encode_blocks(chunks[c], job.A_blocks,
                                            job.B_blocks, job.n)
                        if out is not None:
                            payload[r * q + c] = out
                    self._q.put(("chunk", last_seen, w, jid, c, payload))
            except Exception:
                pass  # the fin below tells the master w is done with the batch
            finally:
                self._q.put(("fin", last_seen, w, None, None, None))

    def _events(self, epoch: int):
        t0 = time.perf_counter()
        fins: set[int] = set()
        expected = self.num_workers - len(self.dead)
        while len(fins) < expected:
            try:
                kind, ep, w, jid, c, payload = self._q.get(
                    timeout=self.timeout)
            except queue.Empty:
                raise _EventSourceDry(
                    f"no worker result within {self.timeout:.1f}s and the "
                    "collected chunks do not decode (hung or dead workers?)"
                ) from None
            if ep != epoch:  # leftover of a previous batch: drop
                continue
            if kind == "fin":
                fins.add(w)
                continue
            yield time.perf_counter() - t0, w, jid, c, payload
        if self.dead:
            raise _EventSourceDry(
                f"worker(s) {self.dead} dead for the whole batch")


class JobMux:
    """Many concurrent coded jobs multiplexed over ONE worker pool.

    The pool is persistent: construct once (picking the event source --
    ``"sim"`` for the rate-based discrete-event simulation, ``"live"`` for
    real threads with injected sleeps; subprocess pools plug in via
    ``runtime.procpool.MuxProcPool``), then call :meth:`run` per batch of
    jobs.  Every batch shares the workers fairly (chunk-major round-robin
    across jobs), tracks decodability per job with its own
    ``IncrementalRankTracker``, stops each job at its first decodable
    chunk prefix, and cancels that job's remaining chunks so the pool's
    capacity flows to the jobs still in flight.  One undecodable job fails
    alone (``MuxResult.error``); the rest of the batch decodes.

    This is the serving building block: ``repro.serving.engine`` submits
    one expert-FFN job per in-flight request per token step, all against
    the same pool and one shared pack cache.
    """

    def __init__(self, num_workers: int, *, source: str = "sim",
                 straggler=None, rng: np.random.Generator | None = None,
                 unit_block_time: float = 1.0,
                 straggler_sleep: dict[int, float] | None = None,
                 dead_workers: Sequence[int] = (),
                 timeout: float = 60.0):
        self.num_workers = num_workers
        if source == "sim":
            self._source = _MuxSimSource(
                num_workers, straggler=straggler, rng=rng,
                unit_block_time=unit_block_time, dead_workers=dead_workers)
        elif source == "live":
            self._source = _MuxLiveSource(
                num_workers, straggler_sleep=straggler_sleep,
                dead_workers=dead_workers, timeout=timeout)
        elif hasattr(source, "submit") and hasattr(source, "job_done"):
            # a source object (e.g. runtime.procpool.MuxProcPool): real OS
            # subprocess workers behind the same submit/job_done protocol
            self._source = source
        else:
            raise ValueError(f"unknown JobMux source {source!r}; expected "
                             "'sim', 'live', or a source object like "
                             "runtime.procpool.MuxProcPool")
        self._next_jid = 0
        self._started = False

    # sources with real resources (threads, processes) need start/close;
    # the context-manager form is the one callers should reach for
    def start(self) -> "JobMux":
        if not self._started:
            self._source.start()
            self._started = True
        return self

    def close(self) -> None:
        if self._started:
            self._source.close()
            self._started = False

    def __enter__(self) -> "JobMux":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def run(self, jobs: Sequence[MuxJob],
            raise_on_error: bool = False) -> list[MuxResult]:
        """Run one batch of concurrent jobs to per-job exact decode."""
        self.start()
        for job in jobs:
            if job.code.num_workers > self.num_workers:
                raise ValueError(
                    f"job {job.tag!r} wants {job.code.num_workers} workers "
                    f"but the pool has {self.num_workers}")
        jids = list(range(self._next_jid, self._next_jid + len(jobs)))
        self._next_jid += len(jobs)
        by_jid = dict(zip(jids, jobs))
        chunkeds = {jid: job.code.chunked(job.num_chunks)
                    for jid, job in by_jid.items()}
        events = self._source.submit(chunkeds, by_jid)
        states, failures = _consume_mux_events(
            chunkeds, events, job_done=self._source.job_done)

        from repro.runtime import pack_cache

        results = []
        for jid in jids:
            job = by_jid[jid]
            if jid in failures:
                if raise_on_error:
                    raise DecodingError(failures[jid])
                results.append(MuxResult(tag=job.tag, report=None,
                                         error=failures[jid]))
                continue
            state = states[jid]
            chunked = chunkeds[jid]
            t0 = time.perf_counter()
            blocks = chunked.decode(state.pairs, state.results_by_row)
            decode_time = time.perf_counter() - t0
            stats = state.decode_stats()
            stats["concurrent_jobs"] = len(jobs)
            stats["pack_cache"] = pack_cache.cache_stats()
            results.append(MuxResult(tag=job.tag, report=ExecutionReport(
                scheme=chunked.name,
                workers_used=int((state.progress > 0).sum()),
                num_workers=job.code.num_workers,
                sim_compute_time=float(state.stop_time),
                decode_wall_time=decode_time,
                total_time=float(state.stop_time) + decode_time,
                decode_stats=stats,
                blocks=blocks,
                num_chunks=job.num_chunks,
                chunks_used=len(state.pairs),
            )))
        return results


# ------------------------------- entry points -------------------------------

def run_coded_job(
    code: CodeInstance,
    blocks_true: Sequence,
    straggler: "StragglerModel",
    rng: np.random.Generator | None = None,
    unit_block_time: float = 1.0,
    check_every: int = 1,
    keep_blocks: bool = False,
    num_chunks: int = 1,
) -> ExecutionReport:
    """Event-driven simulation of one job under a straggler realization.

    ``num_chunks`` > 1 runs the chunk-granular protocol: each worker's task
    splits into that many ordered sub-tasks and the master decodes from the
    first decodable chunk prefix -- at equal total work, never later than
    the atomic run (the atomic arrival set is a subset of the chunked one).
    ``check_every`` is retained for API compatibility; the incremental rank
    tracker already makes the per-event check cheap, so it is ignored.
    """
    del check_every  # superseded by the incremental rank tracker
    from repro.runtime.straggler import StragglerModel  # noqa: F401 (doc type)

    rng = rng or np.random.default_rng(0)
    chunked = code.chunked(num_chunks)
    work = chunked.chunk_work() * unit_block_time
    times = straggler.chunk_completion_times(work, rng)

    state = _consume_events(chunked, _sim_events(chunked, blocks_true, times))

    t0 = time.perf_counter()
    blocks = chunked.decode(state.pairs, state.results_by_row)
    decode_time = time.perf_counter() - t0

    return ExecutionReport(
        scheme=chunked.name,
        workers_used=int((state.progress > 0).sum()),
        num_workers=code.num_workers,
        sim_compute_time=float(state.stop_time),
        decode_wall_time=decode_time,
        total_time=float(state.stop_time) + decode_time,
        decode_stats=state.decode_stats(),
        blocks=blocks if keep_blocks else None,
        num_chunks=num_chunks,
        chunks_used=len(state.pairs),
    )


def run_live_job(
    code: CodeInstance,
    A_blocks: Sequence,
    B_blocks: Sequence,
    n: int,
    straggler_sleep: dict[int, float] | None = None,
    num_threads: int = 4,
    num_chunks: int = 1,
    timeout: float = 60.0,
) -> ExecutionReport:
    """Concurrent execution with real block products and injected sleeps.

    Each worker computes its coded combination chunk by chunk (real sparse
    matmuls; an injected sleep is spread evenly across the chunks) and
    pushes ``(worker, chunk, payload)`` to the master's queue; the master
    consumes through the shared event loop and stops at the first decodable
    chunk prefix -- a straggler's finished chunks count, its unfinished
    ones genuinely never get waited on.

    Workers observe the stop flag before *every* matmul and sleep
    interruptibly (``stop.wait``), and the master joins them with a bounded
    timeout before returning -- an early decode does not leak threads that
    keep computing (or sleeping) the remaining chunks in the background.
    A worker that raises exits through its terminal sentinel, so the master
    stops expecting it instead of waiting out the timeout.
    """
    del num_threads  # one thread per worker, as the protocol prescribes
    straggler_sleep = straggler_sleep or {}
    chunked = code.chunked(num_chunks)
    q_: queue.Queue = queue.Queue()
    stop = threading.Event()

    tasks_by_row = {t.worker: t for t in make_tasks(code.M)}  # row id -> task

    def worker_fn(w: int):
        delay = straggler_sleep.get(w, 0.0) / num_chunks
        row_chunks = {r: tasks_by_row[r].chunks(num_chunks)
                      for r in code.worker_rows[w]}
        try:
            for c in range(num_chunks):
                if delay and stop.wait(delay):  # interruptible sleep
                    return
                payload = {}
                for r, chunks in row_chunks.items():
                    if stop.is_set():
                        return
                    out = encode_blocks(chunks[c], A_blocks, B_blocks, n)
                    if out is not None:
                        payload[r * num_chunks + c] = out
                if stop.is_set():
                    return
                q_.put((w, c, payload))
        except Exception:
            pass  # the sentinel below tells the master w is terminal
        finally:
            q_.put((w, None, None))

    t0 = time.perf_counter()
    threads = [threading.Thread(target=worker_fn, args=(w,), daemon=True,
                                name=f"live-worker-{w}")
               for w in range(code.num_workers)]
    for t in threads:
        t.start()

    try:
        state = _consume_events(
            chunked, _live_events(q_, code.num_workers, num_chunks,
                                  timeout, t0))
    finally:
        stop.set()
        # bounded join: stop-aware workers exit after at most one more block
        # matmul (sleeps wake immediately on stop); the daemon flag stays as
        # the backstop for a truly wedged one
        join_deadline = time.perf_counter() + 5.0
        for t in threads:
            t.join(timeout=max(0.0, join_deadline - time.perf_counter()))
    compute_time = time.perf_counter() - t0

    t1 = time.perf_counter()
    blocks = chunked.decode(state.pairs, state.results_by_row)
    decode_time = time.perf_counter() - t1

    return ExecutionReport(
        scheme=chunked.name,
        workers_used=int((state.progress > 0).sum()),
        num_workers=code.num_workers,
        sim_compute_time=compute_time,
        decode_wall_time=decode_time,
        total_time=compute_time + decode_time,
        decode_stats=state.decode_stats(),
        blocks=blocks,
        num_chunks=num_chunks,
        chunks_used=len(state.pairs),
    )


def run_device_job(
    A,
    B,
    plan,
    mesh=None,
    axis_name: str = "model",
    backend: str = "dense_scan",
    survivors=None,
    repeats: int = 3,
    a_sparse=None,
    out_sharded: bool = False,
) -> ExecutionReport:
    """One coded matmul on a JAX mesh via the SPMD path (thin CodedOp wrapper).

    A, B: (s, r) / (s, t) arrays (numpy or jax).  ``plan`` is a
    ``repro.core.coded_matmul.CodedMatmulPlan``; ``mesh`` defaults to a 1-D
    mesh over every visible device (its axis size must equal
    ``plan.num_workers``).  All execution policy lives in
    ``repro.coded.CodedOp``: backend dispatch, BlockELL packing, the runtime
    pack cache (hit when a caller-supplied ``a_sparse`` recurs), and
    survivor rebinding -- ``survivors`` may be an (N,) liveness mask or an
    (N, q) per-chunk completion mask (partial stragglers contribute their
    finished prefix rows).  This wrapper only builds the op, times its
    jitted apply, and wraps the result in an ``ExecutionReport``.  The
    decode is folded into the device program (one collective), so
    decode_wall_time is reported as 0 and the whole staged computation is
    timed as compute.
    """
    import jax
    import jax.numpy as jnp

    from repro.coded import CodedMatmulConfig, from_plan

    cfg = CodedMatmulConfig(backend=backend, axis_name=axis_name,
                            out_sharded=out_sharded)
    op = from_plan(cfg, plan).bind(mesh)
    if survivors is not None:
        op = op.with_survivors(survivors)

    kw = {}
    if op.needs_pack:
        # pack on host BEFORE staging: the tile pack is static metadata and
        # cannot be derived from a traced operand inside jit.  A caller-
        # supplied a_sparse goes through the op's pack cache (identity-keyed,
        # so recurring ells hit); a freshly built BlockELL bypasses it --
        # caching it would only pin dead entries.
        if a_sparse is not None:
            kw["pack"] = op.pack_for(a_sparse)
        else:
            from repro.sparse.blocksparse import dense_to_block_ell

            ell = dense_to_block_ell(np.asarray(A, dtype=np.float32),
                                     block_size=op.config.block_size)
            kw["pack"] = op.pack_for(ell, use_cache=False)
    A = jnp.asarray(A, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    fn = jax.jit(lambda a, b: op.apply(a, b, **kw))
    fn(A, B).block_until_ready()  # compile outside the timed region
    times = []
    result = None
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        result = fn(A, B)
        result.block_until_ready()
        times.append(time.perf_counter() - t0)
    elapsed = float(np.median(times))

    from repro.runtime import pack_cache

    used = (int(op.survivors.sum()) if op.survivors is not None
            else plan.num_workers)
    return ExecutionReport(
        scheme=f"spmd_{backend}",
        workers_used=used,
        num_workers=plan.num_workers,
        sim_compute_time=elapsed,
        decode_wall_time=0.0,
        total_time=elapsed,
        decode_stats={"backend": backend, "max_degree": plan.max_degree,
                      "on_device_decode": True, "out_sharded": out_sharded,
                      "pack_cache": pack_cache.cache_stats()},
        blocks=[np.asarray(result)],
    )
