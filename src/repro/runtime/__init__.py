from repro.runtime.straggler import (
    StragglerModel,
    NoStragglers,
    SlowWorkers,
    ExponentialStragglers,
    ShiftedExponential,
)
from repro.runtime.executor import (
    ExecutionReport,
    run_coded_job,
    run_device_job,
    run_live_job,
)
