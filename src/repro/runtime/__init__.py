from repro.runtime.straggler import (
    StragglerModel,
    NoStragglers,
    SlowWorkers,
    ExponentialStragglers,
    ShiftedExponential,
)
from repro.runtime.executor import (
    ExecutionReport,
    run_coded_job,
    run_device_job,
    run_live_job,
)

# NOTE: repro.runtime.pack_cache is NOT imported here on purpose -- it pulls
# in repro.core.coded_matmul (and therefore jax) at import time, while this
# package stays importable before XLA_FLAGS are set (the subprocess-isolation
# rule the spmd checks rely on).  Import it as repro.runtime.pack_cache.
