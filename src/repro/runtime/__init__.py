from repro.runtime.straggler import (
    StragglerModel,
    RateModel,
    NoStragglers,
    SlowWorkers,
    SlowWorkerRates,
    LogNormalRates,
    ExponentialStragglers,
    ShiftedExponential,
)
from repro.runtime.executor import (
    ExecutionReport,
    JobMux,
    MuxJob,
    MuxResult,
    run_coded_job,
    run_device_job,
    run_live_job,
)
from repro.runtime.chaos import FaultLedger, FaultPlan, FaultRealization
from repro.runtime.procpool import ProcPool, run_proc_job

__all__ = [
    "StragglerModel",
    "RateModel",
    "NoStragglers",
    "SlowWorkers",
    "SlowWorkerRates",
    "LogNormalRates",
    "ExponentialStragglers",
    "ShiftedExponential",
    "ExecutionReport",
    "JobMux",
    "MuxJob",
    "MuxResult",
    "FaultLedger",
    "FaultPlan",
    "FaultRealization",
    "ProcPool",
    "run_coded_job",
    "run_device_job",
    "run_live_job",
    "run_proc_job",
    "pack_cache",
]


def __getattr__(name):
    # repro.runtime.pack_cache pulls in repro.core.coded_matmul (and
    # therefore jax) at import time, while this package must stay importable
    # before XLA_FLAGS are set (the subprocess-isolation rule the spmd
    # checks rely on) -- so the submodule resolves lazily on first touch.
    if name == "pack_cache":
        import repro.runtime.pack_cache as pack_cache

        return pack_cache
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
