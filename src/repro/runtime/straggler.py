"""Straggler models for the distributed runtime.

The paper's experimental protocol (Section V): "randomly pick s workers that
are running a background thread which increases the computation time."  That
is ``SlowWorkers(s, slowdown)``.  The tail-at-scale literature motivates the
exponential / shifted-exponential variants used in the coded-computation
analyses [4]-[8].

Two model families (DESIGN.md section 8):

* **Completion-time models** (the historical API): ``completion_times``
  maps each worker's nominal work to one finish time.  Under the chunked
  protocol partial progress still needs a timeline, so the base class
  adapts these to chunks by spreading the drawn total linearly across the
  worker's chunk work -- i.e. the historical models are implicitly
  constant-rate within a job.
* **Rate models** (``RateModel``): each worker serves work at a per-job
  service rate (work units per second), which makes partial progress
  well-defined by construction: chunk c completes at
  ``cumsum(work)[c] / rate``.  ``completion_times`` is derived from the
  same rates, so rate models plug into every pre-chunk call site
  unchanged -- the adapter works in both directions.
"""

from __future__ import annotations

import dataclasses

import numpy as np


class StragglerModel:
    """Multiplier/addend applied to each worker's nominal compute time."""

    def completion_times(self, nominal: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def chunk_completion_times(
        self, work: np.ndarray, rng: np.random.Generator
    ) -> np.ndarray:
        """(N, q) times at which each worker finishes its c-th ordered chunk.

        ``work``: (N, q) nominal per-chunk work (e.g. ``ChunkedCode.
        chunk_work`` scaled by the unit block time).  Base-class adapter for
        completion-time models: draw the per-worker total with
        ``completion_times`` (same rng consumption as an atomic run, so
        seeded simulations agree), then place chunk finishes at the
        work-proportional fractions of that total -- constant service rate
        within the job.  Rows are nondecreasing by construction.
        """
        work = np.asarray(work, dtype=np.float64)
        if work.ndim != 2:
            raise ValueError(f"work must be (N, q), got shape {work.shape}")
        totals_work = work.sum(axis=1)
        totals_time = np.asarray(
            self.completion_times(totals_work, rng), dtype=np.float64)
        frac = np.cumsum(work, axis=1)
        safe = np.maximum(totals_work, 1e-300)[:, None]
        return totals_time[:, None] * (frac / safe)


@dataclasses.dataclass
class NoStragglers(StragglerModel):
    def completion_times(self, nominal, rng):
        return np.asarray(nominal, dtype=np.float64)


@dataclasses.dataclass
class SlowWorkers(StragglerModel):
    """Paper's model: s uniformly random workers slowed by a factor."""

    num_slow: int
    slowdown: float = 5.0

    def completion_times(self, nominal, rng):
        t = np.asarray(nominal, dtype=np.float64).copy()
        n = len(t)
        s = min(self.num_slow, n)
        idx = rng.choice(n, size=s, replace=False)
        t[idx] *= self.slowdown
        return t


@dataclasses.dataclass
class ExponentialStragglers(StragglerModel):
    """t_k = nominal_k * (1 + Exp(scale)): heavy right tail on every worker."""

    scale: float = 0.5

    def completion_times(self, nominal, rng):
        t = np.asarray(nominal, dtype=np.float64)
        return t * (1.0 + rng.exponential(self.scale, size=len(t)))


@dataclasses.dataclass
class ShiftedExponential(StragglerModel):
    """Classic coded-computation model: t_k = nominal_k + Exp(scale * nominal_k)."""

    scale: float = 1.0

    def completion_times(self, nominal, rng):
        t = np.asarray(nominal, dtype=np.float64)
        return t + rng.exponential(self.scale * np.maximum(t, 1e-12))


# ------------------------------- rate models --------------------------------

class RateModel(StragglerModel):
    """Per-worker service rates: worker k serves ``rate_k`` work units/sec.

    Subclasses implement ``service_rates``; both APIs derive from it:

    * ``completion_times(nominal) = nominal / rates`` (legacy adapter), and
    * ``chunk_completion_times(work) = cumsum(work, axis=1) / rates`` --
      exact partial progress, no linear-spreading approximation needed.

    Rates are drawn once per call from the SAME rng draw, so a rate model
    used through either API describes one consistent straggler realization.
    """

    def service_rates(self, num_workers: int, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def completion_times(self, nominal, rng):
        nominal = np.asarray(nominal, dtype=np.float64)
        rates = np.asarray(
            self.service_rates(len(nominal), rng), dtype=np.float64)
        return nominal / np.maximum(rates, 1e-300)

    def chunk_completion_times(self, work, rng):
        work = np.asarray(work, dtype=np.float64)
        if work.ndim != 2:
            raise ValueError(f"work must be (N, q), got shape {work.shape}")
        rates = np.asarray(
            self.service_rates(work.shape[0], rng), dtype=np.float64)
        return np.cumsum(work, axis=1) / np.maximum(rates, 1e-300)[:, None]


@dataclasses.dataclass
class SlowWorkerRates(RateModel):
    """Rate-domain twin of ``SlowWorkers``: s random workers at rate
    1/slowdown, the rest at rate 1.  Identical marginal completion times,
    but phrased as rates so chunk progress is defined without adaptation."""

    num_slow: int
    slowdown: float = 5.0

    def service_rates(self, num_workers, rng):
        rates = np.ones(num_workers)
        s = min(self.num_slow, num_workers)
        idx = rng.choice(num_workers, size=s, replace=False)
        rates[idx] = 1.0 / self.slowdown
        return rates


@dataclasses.dataclass
class LogNormalRates(RateModel):
    """Every worker's rate ~ LogNormal(0, sigma), median 1: the smooth
    heavy-tail regime where *every* worker makes partial progress worth
    harvesting (no worker is cleanly "slow" or "fast")."""

    sigma: float = 0.5

    def service_rates(self, num_workers, rng):
        return np.exp(rng.normal(0.0, self.sigma, size=num_workers))
