"""Straggler models for the distributed runtime.

The paper's experimental protocol (Section V): "randomly pick s workers that
are running a background thread which increases the computation time."  That
is ``SlowWorkers(s, slowdown)``.  The tail-at-scale literature motivates the
exponential / shifted-exponential variants used in the coded-computation
analyses [4]-[8].
"""

from __future__ import annotations

import dataclasses

import numpy as np


class StragglerModel:
    """Multiplier/addend applied to each worker's nominal compute time."""

    def completion_times(self, nominal: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError


@dataclasses.dataclass
class NoStragglers(StragglerModel):
    def completion_times(self, nominal, rng):
        return np.asarray(nominal, dtype=np.float64)


@dataclasses.dataclass
class SlowWorkers(StragglerModel):
    """Paper's model: s uniformly random workers slowed by a factor."""

    num_slow: int
    slowdown: float = 5.0

    def completion_times(self, nominal, rng):
        t = np.asarray(nominal, dtype=np.float64).copy()
        n = len(t)
        s = min(self.num_slow, n)
        idx = rng.choice(n, size=s, replace=False)
        t[idx] *= self.slowdown
        return t


@dataclasses.dataclass
class ExponentialStragglers(StragglerModel):
    """t_k = nominal_k * (1 + Exp(scale)): heavy right tail on every worker."""

    scale: float = 0.5

    def completion_times(self, nominal, rng):
        t = np.asarray(nominal, dtype=np.float64)
        return t * (1.0 + rng.exponential(self.scale, size=len(t)))


@dataclasses.dataclass
class ShiftedExponential(StragglerModel):
    """Classic coded-computation model: t_k = nominal_k + Exp(scale * nominal_k)."""

    scale: float = 1.0

    def completion_times(self, nominal, rng):
        t = np.asarray(nominal, dtype=np.float64)
        return t + rng.exponential(self.scale * np.maximum(t, 1e-12))
