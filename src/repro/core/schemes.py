"""Baseline coded-computation schemes the paper benchmarks against.

Every scheme is expressed in the *block domain*: the mn block products
C_ij = A_i^T B_j are the unknowns, a worker's results are rows of a generator
matrix M applied to them.  This uniform view supports the completion-time and
decode-time benchmarks (Figs. 5-6, Table III).

Per-worker local cost is reported as a *cost factor*: local compute relative
to one uncoded block product on the same (sparse) inputs.  For sum-of-products
codes (sparse code, LT, sparse MDS) it equals the row degree -- the worker
evaluates each A_i^T B_j separately.  For product-of-coded-matrices codes
(polynomial, MDS, product code) the coded inputs densify m- and n-fold, so the
single product costs ~m*n uncoded block products (paper Fig. 1, Table I).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from repro.core import degree as degree_lib
from repro.core.decoder import (
    DecodingError,
    gaussian_decode,
    hybrid_decode,
    peel_schedule,
    apply_schedule,
)
from repro.core.encoder import (
    SparseCodeSpec,
    chunk_expand,
    generate_coefficient_matrix,
)


@dataclasses.dataclass(frozen=True)
class SchemeInvariants:
    """Static decodability profile of a scheme design.

    This is the paper-derived metadata ``repro.analysis`` validates every
    registered scheme against -- it lives next to the builders so a new
    scheme declares its own bound instead of the checker hardcoding one.

    optimal_workers -- the information-theoretic minimum worker count whose
        results decode: ``"mn"`` (one useful row per worker), ``"m"`` (the
        MDS-on-A code: each worker carries a full coded column of C), or
        ``"all"`` (uncoded: no redundancy, every worker is critical).
    exact -- worst-case recovery threshold EQUALS the optimum (the MDS
        property; any optimal-size subset decodes).
    mean_overhead / max_overhead -- for non-exact designs, the allowed
        empirical recovery overhead beyond the optimum, as a fraction of it
        (plus a small additive slack applied by the checker).  The paper's
        sparse code is near-optimal: Theta(mn) with small constants.
    dense_rows -- generator rows are dense (row weight ~ mn, the
        product-of-coded-matrices designs); sparse designs keep row weight
        O(log mn) and the checker enforces that cap.
    cond_warn -- condition-number budget for worst-case survivor subsets of
        the device plan's coefficient matrix; beyond it the f32 device
        decode is flagged.  Random sparse designs sit comfortably under the
        1e8 default; product-of-MDS generators are intrinsically worse
        conditioned on near-minimal subsets and declare a looser budget.
    """

    optimal_workers: str = "mn"
    exact: bool = False
    mean_overhead: float = 0.5
    max_overhead: float = 1.0
    dense_rows: bool = False
    cond_warn: float = 1e8

    def __post_init__(self):
        if self.optimal_workers not in ("mn", "m", "all"):
            raise ValueError(
                f"optimal_workers must be mn|m|all, got "
                f"{self.optimal_workers!r}")

    def optimal(self, m: int, n: int, num_workers: int) -> int:
        if self.optimal_workers == "all":
            return num_workers
        return m if self.optimal_workers == "m" else m * n


#: per-scheme profiles, keyed by registry name (repro.coded.registry wires
#: these onto the ``Scheme`` entries at registration)
INVARIANTS: dict[str, SchemeInvariants] = {
    "uncoded": SchemeInvariants(optimal_workers="all", exact=True,
                                mean_overhead=0.0, max_overhead=0.0),
    "sparse_code": SchemeInvariants(mean_overhead=0.30, max_overhead=0.80),
    "lt_code": SchemeInvariants(mean_overhead=0.80, max_overhead=1.60),
    "sparse_mds": SchemeInvariants(mean_overhead=0.30, max_overhead=0.80),
    "polynomial": SchemeInvariants(exact=True, mean_overhead=0.0,
                                   max_overhead=0.0, dense_rows=True),
    "mds": SchemeInvariants(optimal_workers="m", exact=True,
                            mean_overhead=0.0, max_overhead=0.0,
                            dense_rows=True),
    "product": SchemeInvariants(mean_overhead=0.80, max_overhead=1.60,
                                dense_rows=True, cond_warn=1e11),
}


@dataclasses.dataclass
class CodeInstance:
    """A realized code: worker -> generator rows, costs, decode policy."""

    name: str
    M: sp.csr_matrix                 # (R, mn) generator in the block domain
    worker_rows: list[list[int]]     # worker k owns these rows of M
    cost_factor: np.ndarray          # (N,) local compute vs one block product
    decode_kind: str                 # "hybrid" | "peel" | "dense"

    @property
    def num_workers(self) -> int:
        return len(self.worker_rows)

    @property
    def mn(self) -> int:
        return self.M.shape[1]

    def rows_of(self, workers: list[int]) -> list[int]:
        return [r for w in workers for r in self.worker_rows[w]]

    def can_decode(self, workers: list[int]) -> bool:
        return _can_decode_rows(self.decode_kind, self.mn,
                                self.M[self.rows_of(workers)])

    def decode(self, workers: list[int], results_by_row: dict[int, object]):
        rows = self.rows_of(workers)
        sub = self.M[rows]
        data = [results_by_row[r] for r in rows]
        return _decode_rows(self.decode_kind, sub, data)

    def chunked(self, num_chunks: int) -> "ChunkedCode":
        """Chunk-granular view of this code (partial-straggler protocol).

        Every worker's task splits into ``num_chunks`` ordered sub-tasks;
        each sub-task is one row of the chunk-expanded coefficient matrix,
        so the master can decode from completed *chunks* instead of whole
        tasks.  ``num_chunks == 1`` is the atomic protocol, bit-for-bit.
        Works for every registered scheme: chunking is defined on the
        generator matrix, not on any scheme-specific structure.
        """
        return ChunkedCode(base=self, num_chunks=num_chunks,
                           M=chunk_expand(self.M, num_chunks))


def _decode_rows(decode_kind: str, sub: sp.csr_matrix, data: list):
    """Decode collected rows with a CodeInstance decode policy."""
    if decode_kind == "hybrid":
        blocks, _ = hybrid_decode(sub, data)
        return blocks
    if decode_kind == "peel":
        sched, _ = peel_schedule(sub, check_rank=False, root_pick="fail")
        return apply_schedule(sched, data)
    return gaussian_decode(sub, data)


def _can_decode_rows(decode_kind: str, mn: int, sub: sp.csr_matrix) -> bool:
    """Decodability of collected rows under a CodeInstance decode policy --
    the one place the rule lives, shared by the atomic and chunked views."""
    if sub.shape[0] < mn:
        return False
    if decode_kind == "peel":
        try:
            peel_schedule(sub, check_rank=False, root_pick="fail")
            return True
        except (DecodingError, ValueError):
            return False
    return np.linalg.matrix_rank(sub.toarray()) == mn


@dataclasses.dataclass
class ChunkedCode:
    """Chunk-granular view of a ``CodeInstance``.

    Identifiers are ``(worker, chunk)`` pairs: worker w's chunk c stands for
    the c-th ordered sub-task of EACH of w's generator rows (one sub-task per
    row for the common one-row-per-worker schemes).  The expanded matrix M
    has row ``r * num_chunks + c`` = chunk c of base row r (see
    ``encoder.chunk_expand``); ``rows_of``/``can_decode``/``decode`` mirror
    the ``CodeInstance`` API but consume (worker, chunk) ids, and
    ``chunk_work`` exposes the per-chunk share of each worker's cost factor
    so straggler models can place partial progress on the timeline.
    """

    base: CodeInstance
    num_chunks: int
    M: sp.csr_matrix          # (R * num_chunks, mn) chunk-expanded generator

    @property
    def name(self) -> str:
        q = self.num_chunks
        return self.base.name if q == 1 else f"{self.base.name}/q{q}"

    @property
    def num_workers(self) -> int:
        return self.base.num_workers

    @property
    def mn(self) -> int:
        return self.base.mn

    def expanded_rows(self, worker: int, chunk: int) -> list[int]:
        """Nonempty expanded-M rows delivered by (worker, chunk)."""
        q = self.num_chunks
        rows = [r * q + chunk for r in self.base.worker_rows[worker]]
        return [r for r in rows if self.M.indptr[r + 1] > self.M.indptr[r]]

    def rows_of(self, pairs) -> list[int]:
        """Expanded-M rows of the given (worker, chunk) arrivals, in order."""
        return [r for w, c in pairs for r in self.expanded_rows(w, c)]

    def chunk_work(self) -> np.ndarray:
        """(N, num_chunks) nominal work per chunk, in block-product units.

        Worker w's cost factor is split across its chunks proportionally to
        the slots each chunk carries (summed over the worker's rows), so the
        per-worker total equals the atomic cost exactly -- "equal total
        work" between chunked and atomic runs by construction.
        """
        q = self.num_chunks
        N = self.num_workers
        work = np.zeros((N, q))
        nnz_exp = np.diff(self.M.indptr)              # per expanded row
        for w in range(N):
            slots = np.zeros(q)
            for r in self.base.worker_rows[w]:
                slots += nnz_exp[r * q:(r + 1) * q]
            total = slots.sum()
            if total > 0:
                work[w] = self.base.cost_factor[w] * slots / total
        return work

    def can_decode(self, pairs) -> bool:
        return _can_decode_rows(self.base.decode_kind, self.mn,
                                self.M[self.rows_of(pairs)])

    def decode(self, pairs, results_by_row: dict[int, object]):
        """Decode from chunk results (keyed by expanded-M row id)."""
        rows = self.rows_of(pairs)
        sub = self.M[rows]
        data = [results_by_row[r] for r in rows]
        return _decode_rows(self.base.decode_kind, sub, data)


def uncoded(m: int, n: int) -> CodeInstance:
    """Each of mn workers computes one block; master waits for all."""
    d = m * n
    return CodeInstance(
        name="uncoded",
        M=sp.identity(d, format="csr"),
        worker_rows=[[k] for k in range(d)],
        cost_factor=np.ones(d),
        decode_kind="dense",  # identity: decode is a no-op relabel
    )


def sparse_code(
    m: int, n: int, N: int, distribution: str = "wave_soliton",
    weight_kind: str = "paper", seed: int = 0,
) -> CodeInstance:
    """The paper's (P, S)-sparse code."""
    spec = SparseCodeSpec(m=m, n=n, num_workers=N, distribution=distribution,
                          weight_kind=weight_kind, seed=seed)
    M = generate_coefficient_matrix(spec)
    deg = np.diff(M.indptr)
    return CodeInstance(
        name=f"sparse_code[{distribution}]",
        M=M,
        worker_rows=[[k] for k in range(N)],
        cost_factor=deg.astype(np.float64),
        decode_kind="hybrid",
    )


def lt_code(m: int, n: int, N: int, seed: int = 0) -> CodeInstance:
    """LT code: Robust Soliton degrees, unit weights, peeling-only decode."""
    d = m * n
    rng = np.random.default_rng(seed)
    probs = degree_lib.robust_soliton(d)
    rows, cols, vals = [], [], []
    for k in range(N):
        deg = int(degree_lib.sample_degrees(rng, probs, 1)[0])
        chosen = rng.choice(d, size=deg, replace=False)
        rows.extend([k] * deg)
        cols.extend(chosen.tolist())
        vals.extend([1.0] * deg)
    M = sp.csr_matrix((vals, (rows, cols)), shape=(N, d))
    deg = np.diff(M.indptr)
    return CodeInstance(
        name="lt_code",
        M=M,
        worker_rows=[[k] for k in range(N)],
        cost_factor=deg.astype(np.float64),
        decode_kind="peel",
    )


def sparse_mds_code(m: int, n: int, N: int, alpha: float = 2.0, seed: int = 0) -> CodeInstance:
    """Sparse MDS [14]: Bernoulli(alpha*ln(d)/d) generator, Gaussian decode."""
    d = m * n
    rng = np.random.default_rng(seed)
    p = min(1.0, alpha * np.log(max(d, 2)) / d)
    mask = rng.random((N, d)) < p
    # Guarantee no empty rows (a worker with nothing to do is useless).
    for k in range(N):
        if not mask[k].any():
            mask[k, rng.integers(d)] = True
    vals = rng.standard_normal((N, d)) * mask
    M = sp.csr_matrix(vals)
    deg = np.diff(M.indptr)
    return CodeInstance(
        name="sparse_mds",
        M=M,
        worker_rows=[[k] for k in range(N)],
        cost_factor=deg.astype(np.float64),
        decode_kind="dense",
    )


def polynomial_code(m: int, n: int, N: int, seed: int = 0) -> CodeInstance:
    """Polynomial code [7]: worker k computes (sum_i A_i x^i)^T (sum_j B_j x^{jm}).

    Block-domain weight: M[k, i*n+j] = x_k^{i + j*m}.  Any mn rows form a
    generalized Vandermonde (full rank).  Evaluation points are Chebyshev
    nodes in [-1, 1] for f64 conditioning (the paper uses integers over a
    finite field; over R that is numerically unusable past mn ~ 9).
    """
    d = m * n
    x = np.cos(np.pi * (2 * np.arange(1, N + 1) - 1) / (2 * N))  # distinct
    i_idx, j_idx = np.meshgrid(np.arange(m), np.arange(n), indexing="ij")
    expo = (i_idx + j_idx * m).reshape(-1)  # flat col i*n+j
    M = np.power(x[:, None], expo[None, :])
    return CodeInstance(
        name="polynomial",
        M=sp.csr_matrix(M),
        worker_rows=[[k] for k in range(N)],
        cost_factor=np.full(N, float(m * n)),  # coded inputs densify m*n-fold
        decode_kind="dense",
    )


def mds_code(m: int, n: int, N: int, seed: int = 0) -> CodeInstance:
    """(N, m) MDS on A only [5]: worker u computes A~_u^T B (all of B).

    Block domain: worker u owns n rows; row (u, j) has weights G[u, i] on
    blocks (i, j).  Decodable from any m workers.  Gaussian G is MDS w.p. 1.
    """
    d = m * n
    rng = np.random.default_rng(seed)
    G = rng.standard_normal((N, m))
    rows, cols, vals = [], [], []
    worker_rows = []
    r = 0
    for u in range(N):
        mine = []
        for j in range(n):
            for i in range(m):
                rows.append(r)
                cols.append(i * n + j)
                vals.append(G[u, i])
            mine.append(r)
            r += 1
        worker_rows.append(mine)
    M = sp.csr_matrix((vals, (rows, cols)), shape=(r, d))
    return CodeInstance(
        name="mds",
        M=M,
        worker_rows=worker_rows,
        cost_factor=np.full(N, float(m * n)),  # dense-coded A against full B
        decode_kind="dense",
    )


def product_code(m: int, n: int, N: int, seed: int = 0) -> CodeInstance:
    """Product code [9]: grid of workers, MDS-coded along each input.

    Worker (u, v) computes A~_u^T B~_v with A~ = sum_i G[u,i] A_i and
    B~ = sum_j H[v,j] B_j, so M = G (x) H (Kronecker).  Grid dimensions are
    the largest (mu, nv) with mu*nv <= N, mu >= m, nv >= n.
    """
    rng = np.random.default_rng(seed)
    mu = max(m, int(np.floor(np.sqrt(N * m / n))))
    nv = max(n, N // mu)
    while mu * nv > N and mu > m:
        mu -= 1
        nv = max(n, N // mu)
    G = rng.standard_normal((mu, m))
    H = rng.standard_normal((nv, n))
    M = np.kron(G, H)  # rows ordered (u, v) -> u * nv + v; cols (i, j) -> i*n+j
    num = mu * nv
    return CodeInstance(
        name="product",
        M=sp.csr_matrix(M),
        worker_rows=[[k] for k in range(num)],
        cost_factor=np.full(num, float(m * n)),
        decode_kind="dense",
    )


SCHEMES = {
    "uncoded": uncoded,
    "sparse_code": sparse_code,
    "lt_code": lt_code,
    "sparse_mds": sparse_mds_code,
    "polynomial": polynomial_code,
    "mds": mds_code,
    "product": product_code,
}
