"""Optimal degree-distribution design (paper Section IV-C, model (46)).

    min   sum_k k p_k                      (average degree = worker overhead)
    s.t.  P(M full rank) > p_c             (surrogate: perfect-matching prob)
          [1 - Omega'(x)/d]^{d+c} <= 1 - x - c0 sqrt((1-x)/d)   on a grid
          p in simplex(d)

The decodability constraint is *linear* in p after rearrangement:

    Omega'(x) >= d * (1 - rhs(x)^{1/(d+c)}),    Omega'(x) = sum_k k p_k x^{k-1}

so with the matching constraint dropped the problem is an LP
(``optimize_degree_distribution(..., method="lp")``).

For the full-rank constraint: the paper's formula (48) is a sequential
approximation that grossly *underestimates* the true matching probability
for d >~ 10 (see repro.core.matching), which would force absurdly dense
designs.  The default method="hybrid" therefore solves the decodability LP
and then *validates* the matching probability by Monte-Carlo, blending the LP
solution toward Wave Soliton (bisection on the blend weight) until the
empirical probability clears p_m -- a numerically honest stand-in for the
paper's Table IV procedure.  method="slsqp" keeps the paper-literal program
(formula (48) as the constraint) for reference.
"""

from __future__ import annotations

import numpy as np
import scipy.optimize as opt

from repro.core.degree import robust_soliton, wave_soliton
from repro.core.matching import empirical_matching_prob, perfect_matching_prob


def _decodability_rows(d: int, c: float, c0: float, b: float, max_degree: int,
                       grid: int = 64):
    """Linear constraint rows:  A @ p >= lo  encoding Omega'(x) >= g(x)."""
    xs = np.linspace(0.0, 1.0 - b / d, grid)
    ks = np.arange(1, max_degree + 1)
    A = ks[None, :] * xs[:, None] ** (ks[None, :] - 1)  # Omega'(x) coefficients
    rhs = 1.0 - xs - c0 * np.sqrt((1.0 - xs) / d)
    rhs = np.clip(rhs, 1e-12, 1.0)
    lo = d * (1.0 - rhs ** (1.0 / (d + c)))
    return A, lo


def optimize_degree_distribution(
    d: int,
    max_degree: int | None = None,
    p_m: float = 0.95,
    c: float = 2.0,
    c0: float = 0.1,
    b: float = 1.0,
    method: str = "hybrid",
    mc_trials: int = 200,
    seed: int = 0,
) -> np.ndarray:
    """Design a degree distribution for mn = d blocks.

    Returns probabilities over degrees 1..d (mass beyond max_degree is zero).
    """
    max_degree = max_degree or min(d, 8)
    A, lo = _decodability_rows(d, c, c0, b, max_degree)
    ks = np.arange(1, max_degree + 1, dtype=np.float64)

    def lift(p_small: np.ndarray) -> np.ndarray:
        p = np.zeros(d)
        p[:max_degree] = p_small
        return p

    if method == "lp":
        # LP: decodability + simplex (+ a floor on p_1 so peeling can start:
        # the matching constraint is dropped, p_1 >= 1/d stands in for it).
        A_ub = -A
        b_ub = -lo
        bounds = [(1.0 / d if k == 0 else 0.0, 1.0) for k in range(max_degree)]
        res = opt.linprog(
            ks, A_ub=A_ub, b_ub=b_ub,
            A_eq=np.ones((1, max_degree)), b_eq=[1.0],
            bounds=bounds, method="highs",
        )
        if not res.success:
            raise RuntimeError(f"LP design infeasible for d={d}: {res.message}")
        return lift(res.x)

    if method == "hybrid":
        base = optimize_degree_distribution(
            d, max_degree=max_degree, c=c, c0=c0, b=b, method="lp"
        )
        wave = wave_soliton(d)
        rng = np.random.default_rng(seed)

        def ok(p):
            return empirical_matching_prob(p, trials=mc_trials,
                                           rng=np.random.default_rng(seed)) >= p_m

        if ok(base):
            return base
        if not ok(wave):
            # Even Wave Soliton misses p_m at this d: return the heavier one.
            return wave
        lo_w, hi_w = 0.0, 1.0  # blend weight toward wave
        for _ in range(8):
            mid = 0.5 * (lo_w + hi_w)
            if ok((1 - mid) * base + mid * wave):
                hi_w = mid
            else:
                lo_w = mid
        return (1 - hi_w) * base + hi_w * wave

    # SLSQP with the paper-literal matching probability formula (48).
    x0 = robust_soliton(d)[:max_degree]
    x0 = x0 / x0.sum()

    cons = [
        {"type": "eq", "fun": lambda p: p.sum() - 1.0},
        {"type": "ineq", "fun": lambda p: A @ p - lo},  # decodability
        {"type": "ineq",
         "fun": lambda p: perfect_matching_prob(lift(np.clip(p, 0, 1))) - p_m},
    ]
    res = opt.minimize(
        lambda p: float(ks @ p),
        x0,
        method="SLSQP",
        bounds=[(0.0, 1.0)] * max_degree,
        constraints=cons,
        options={"maxiter": 300, "ftol": 1e-9},
    )
    if not res.success:
        # Fall back to the LP relaxation rather than failing the pipeline.
        return optimize_degree_distribution(
            d, max_degree=max_degree, p_m=p_m, c=c, c0=c0, b=b, method="lp"
        )
    p = np.clip(res.x, 0.0, None)
    return lift(p / p.sum())
