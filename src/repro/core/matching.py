"""Perfect-matching probability of the random balanced bipartite graph.

Paper Appendix D, equations (48)-(49): for G(V1, V2, P) with |V1| = |V2| = d
and right-node degrees drawn from P, the probability that G contains a
perfect matching factorizes (under the sequential-matching argument) as

    P(match) = prod_{s=1..d} (1 - p_0^(s)),

where P^(s) is the "degree evolution": p_k^(s) = probability a right node has
exactly k neighbours inside a fixed subset of V1 of size s, computed by the
downward recursion (49):

    p_k^(s) = p_k^(s+1) * (1 - k/(s+1)) + p_{k+1}^(s+1) * (k+1)/(s+1).

This quantity lower-bounds the full-rank probability of the coefficient
matrix M via Schwartz-Zippel (paper Section IV-A) and is the tractable
surrogate used by the LP design (Section IV-C).
"""

from __future__ import annotations

import numpy as np


def degree_evolution(p: np.ndarray) -> np.ndarray:
    """All P^(s) for s = d..1.

    Input: p over degrees 1..d (paper's P, with implicit p_0 = 0).
    Returns array E of shape (d+1, d+1): E[s, k] = p_k^(s), rows s=0..d.
    """
    d = len(p)
    E = np.zeros((d + 1, d + 1))
    E[d, 1 : d + 1] = p  # P^(d) = P, p_0^(d) = 0
    for s in range(d - 1, -1, -1):
        k = np.arange(0, s + 1)
        # p_k^(s) = p_k^(s+1) (1 - k/(s+1)) + p_{k+1}^(s+1) (k+1)/(s+1)
        E[s, : s + 1] = E[s + 1, : s + 1] * (1.0 - k / (s + 1.0)) + E[
            s + 1, 1 : s + 2
        ] * ((k + 1.0) / (s + 1.0))
    return E


def perfect_matching_prob(p: np.ndarray) -> float:
    """P(G(V1,V2,P) contains a perfect matching), paper eq. (48).

    REPRODUCTION FINDING (see EXPERIMENTS.md): the paper presents (48) as an
    "exact formula", but it is a *sequential greedy* factorization -- it
    treats "vertex v_s has a neighbour among the s remaining left vertices"
    as independent events under the unconditioned degree evolution, and a
    greedy failure as a global failure.  Monte-Carlo (``
    empirical_matching_prob``) shows (48) underestimates badly as d grows
    (e.g. Wave Soliton d=16: (48) gives 0.02, truth is ~0.80).  We keep (48)
    verbatim for fidelity and use the Monte-Carlo estimate where an accurate
    value matters (LP design validation).
    """
    E = degree_evolution(np.asarray(p, dtype=np.float64))
    d = len(p)
    probs = 1.0 - E[1 : d + 1, 0]  # (1 - p_0^(s)) for s = 1..d
    return float(np.prod(probs))


def empirical_matching_prob(
    p: np.ndarray, trials: int = 200, rng: np.random.Generator | None = None
) -> float:
    """Monte-Carlo estimate via maximum bipartite matching (validation)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import maximum_bipartite_matching

    rng = rng or np.random.default_rng(0)
    d = len(p)
    degrees = np.arange(1, d + 1)
    hits = 0
    for _ in range(trials):
        rows, cols = [], []
        for v in range(d):
            deg = rng.choice(degrees, p=p)
            nbrs = rng.choice(d, size=deg, replace=False)
            rows.extend([v] * deg)
            cols.extend(nbrs.tolist())
        G = sp.csr_matrix((np.ones(len(rows)), (rows, cols)), shape=(d, d))
        match = maximum_bipartite_matching(G, perm_type="column")
        hits += int((match >= 0).all())
    return hits / trials
