"""Encoder for the (P, S)-sparse code (paper Definition 1).

Block convention: A is split into m column blocks, B into n column blocks;
block (i, j) of C = A^T B is C_ij = A_i^T B_j and maps to flat column index
``col = i * n + j`` of the coefficient matrix M in R^{N x mn}.

Worker k's task is the weighted combination  C~_k = sum_{(i,j)} w^k_ij C_ij
with the number of nonzero weights drawn from a degree distribution P and the
nonzero weight values drawn i.i.d. uniform from the finite set S (paper uses
S = [m^2 n^2]; we default to that and also offer numerically friendlier sets).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np
import scipy.sparse as sp

from repro.core import degree as degree_lib


def block_col(i: int, j: int, n: int) -> int:
    return i * n + j


def chunk_slices(length: int, num_chunks: int) -> list[slice]:
    """Balanced ordered split of ``range(length)`` into ``num_chunks`` slices.

    The first ``length % num_chunks`` chunks get one extra element; chunks
    beyond ``length`` are empty.  This is THE chunk boundary rule -- the host
    task model, the chunk-expanded coefficient matrix, and the device
    per-chunk survivor masks all call it, so a "chunk" means the same slot
    range everywhere.
    """
    if num_chunks < 1:
        raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
    base, extra = divmod(length, num_chunks)
    out, lo = [], 0
    for c in range(num_chunks):
        hi = lo + base + (1 if c < extra else 0)
        out.append(slice(lo, hi))
        lo = hi
    return out


def col_block(col: int, n: int) -> tuple[int, int]:
    return col // n, col % n


def make_weight_set(m: int, n: int, kind: str = "paper") -> np.ndarray:
    """The finite set S from which nonzero weights are drawn.

    kind="paper":       S = {1, ..., m^2 n^2}  (Definition 1)
    kind="symmetric":   S = {±1, ..., ±ceil(m^2n^2/2)}  (better f32 conditioning,
                        same Schwartz-Zippel guarantee: |S| >= (mn)^2 = deg(det)^2)
    kind="unit":        S = {+1, -1} (binary-ish; NOT S-Z safe, for ablations)
    """
    d2 = (m * n) ** 2
    if kind == "paper":
        return np.arange(1, d2 + 1, dtype=np.float64)
    if kind == "symmetric":
        half = (d2 + 1) // 2
        vals = np.arange(1, half + 1, dtype=np.float64)
        return np.concatenate([vals, -vals])
    if kind == "unit":
        return np.array([1.0, -1.0])
    raise ValueError(f"unknown weight set kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class SparseCodeSpec:
    """Static description of a (P, S)-sparse code instance."""

    m: int
    n: int
    num_workers: int
    distribution: str = "wave_soliton"
    weight_kind: str = "paper"
    seed: int = 0

    @property
    def mn(self) -> int:
        return self.m * self.n

    def degree_probs(self) -> np.ndarray:
        return degree_lib.get_distribution(self.distribution, self.mn)


@dataclasses.dataclass(frozen=True)
class CodedTask:
    """One worker's assignment: which blocks, with which weights."""

    worker: int
    cols: np.ndarray     # flat block indices, shape (degree,)
    weights: np.ndarray  # same shape

    #: chunk index within the worker's ordered sub-task stream (None = the
    #: whole task; set by ``chunks()``)
    chunk: int | None = None

    @property
    def degree(self) -> int:
        return len(self.cols)

    def pairs(self, n: int) -> list[tuple[int, int, float]]:
        return [(c // n, c % n, float(w)) for c, w in zip(self.cols, self.weights)]

    def chunks(self, num_chunks: int) -> list["CodedTask"]:
        """Ordered chunk decomposition of this task (partial-straggler model).

        The slot list is split into ``num_chunks`` contiguous sub-tasks via
        ``chunk_slices``; sub-task c computes the partial combination over its
        slots, so the full task result is the (ordered) sum of its chunk
        results.  Chunks past the degree are empty tasks (zero contribution).
        """
        return [
            CodedTask(worker=self.worker, cols=self.cols[sl],
                      weights=self.weights[sl], chunk=c)
            for c, sl in enumerate(chunk_slices(self.degree, num_chunks))
        ]


def generate_coefficient_matrix(
    spec: SparseCodeSpec, rng: np.random.Generator | None = None
) -> sp.csr_matrix:
    """Sample the coefficient matrix M in R^{N x mn} per Definition 1."""
    rng = rng or np.random.default_rng(spec.seed)
    d = spec.mn
    probs = spec.degree_probs()
    S = make_weight_set(spec.m, spec.n, spec.weight_kind)
    degrees = degree_lib.sample_degrees(rng, probs, spec.num_workers)
    rows, cols, vals = [], [], []
    for k in range(spec.num_workers):
        deg = int(degrees[k])
        chosen = rng.choice(d, size=deg, replace=False)
        w = rng.choice(S, size=deg)
        rows.extend([k] * deg)
        cols.extend(chosen.tolist())
        vals.extend(w.tolist())
    M = sp.csr_matrix(
        (np.asarray(vals, dtype=np.float64), (rows, cols)),
        shape=(spec.num_workers, d),
    )
    return M


def chunk_expand(M: sp.spmatrix, num_chunks: int) -> sp.csr_matrix:
    """Chunk-expanded coefficient matrix: row r splits into ``num_chunks``
    ordered chunk rows.

    Expanded row ``r * num_chunks + c`` carries the slots of chunk c of row r
    (``chunk_slices`` over the row's nonzero slot list, CSR order).  Summing a
    row's chunk rows reproduces the original row exactly (disjoint supports),
    so the expanded system is a refinement of M: every completed *chunk* is
    one usable equation over the mn unknown blocks, which is what lets the
    master decode from partial stragglers.  ``num_chunks == 1`` returns M
    itself (same sparsity, same values).
    """
    M = sp.csr_matrix(M)
    if num_chunks == 1:
        return M
    R, d = M.shape
    rows, cols, vals = [], [], []
    for r in range(R):
        lo, hi = M.indptr[r], M.indptr[r + 1]
        for c, sl in enumerate(chunk_slices(hi - lo, num_chunks)):
            idx = M.indices[lo + sl.start:lo + sl.stop]
            rows.extend([r * num_chunks + c] * len(idx))
            cols.extend(idx.tolist())
            vals.extend(M.data[lo + sl.start:lo + sl.stop].tolist())
    return sp.csr_matrix(
        (np.asarray(vals, dtype=M.dtype), (rows, cols)),
        shape=(R * num_chunks, d))


def make_tasks(M: sp.csr_matrix) -> list[CodedTask]:
    """Turn rows of the coefficient matrix into per-worker tasks."""
    tasks = []
    for k in range(M.shape[0]):
        lo, hi = M.indptr[k], M.indptr[k + 1]
        tasks.append(
            CodedTask(worker=k, cols=M.indices[lo:hi].copy(), weights=M.data[lo:hi].copy())
        )
    return tasks


def split_blocks(X: np.ndarray | sp.spmatrix, parts: int, axis: int = 1) -> list:
    """Evenly split a matrix into `parts` blocks along `axis` (pads nothing;
    requires divisibility, as in the paper's setup)."""
    size = X.shape[axis]
    if size % parts:
        raise ValueError(f"dimension {size} not divisible into {parts} blocks")
    step = size // parts
    out = []
    for p in range(parts):
        sl = slice(p * step, (p + 1) * step)
        out.append(X[:, sl] if axis == 1 else X[sl, :])
    return out


def compute_block_products(
    A_blocks: Sequence, B_blocks: Sequence
) -> list[list]:
    """All mn uncoded block products C_ij = A_i^T B_j (oracle/test helper)."""
    return [[(Ai.T @ Bj) for Bj in B_blocks] for Ai in A_blocks]


def encode_blocks(task: CodedTask, A_blocks: Sequence, B_blocks: Sequence, n: int):
    """Execute one coded task: C~ = sum w_ij A_i^T B_j.

    Works for numpy arrays and scipy.sparse matrices alike.  The sum is
    evaluated product-by-product (the combination does not factorize), which
    is exactly why the paper's per-worker overhead is `degree x` one block
    product, i.e. Theta(ln(mn)) on average under Wave Soliton.
    """
    acc = None
    for c, w in zip(task.cols, task.weights):
        i, j = c // n, c % n
        term = (A_blocks[i].T @ B_blocks[j]) * w
        acc = term if acc is None else acc + term
    return acc
