"""Degree distributions for the (P, S)-sparse code.

The degree of a coded task is the number of nonzero weights w_ij in the
linear combination  C~_k = sum_ij w_ij A_i^T B_j.  The paper's central design
is the Wave Soliton distribution (Definition 2): a Soliton distribution capped
at mn with probability mass moved from degree 2 to the tail, giving average
degree Theta(ln(mn)) while keeping enough ripple mass for peeling decoding.
"""

from __future__ import annotations

import numpy as np

# Normalizing factor tau = 35/18 (paper, Definition 2).  With
#   p_1 = tau/d,  p_2 = tau/70,  p_k = tau/(k(k-1)) for 3 <= k <= d
# the telescoping sum gives  sum_k p_k = tau * (1/70 + 1/2) = 1 exactly.
WAVE_TAU = 35.0 / 18.0


def wave_soliton(d: int) -> np.ndarray:
    """Wave Soliton distribution P_w over degrees 1..d (paper eq. (7))."""
    if d < 3:
        # Degenerate tiny cases: fall back to a proper renormalized cap.
        p = np.zeros(d)
        p[0] = WAVE_TAU / d
        if d >= 2:
            p[1] = WAVE_TAU / 70.0
        return p / p.sum()
    k = np.arange(1, d + 1, dtype=np.float64)
    p = WAVE_TAU / (k * (k - 1.0 + (k == 1)))  # placeholder for k>=3 shape
    p[0] = WAVE_TAU / d
    p[1] = WAVE_TAU / 70.0
    p[2:] = WAVE_TAU / (k[2:] * (k[2:] - 1.0))
    # Exact normalization (analytically sums to 1 + tau/d - tau/d; tiny float
    # residue is folded into the largest mass so sampling is well-defined).
    p /= p.sum()
    return p


def ideal_soliton(d: int) -> np.ndarray:
    """Ideal Soliton: p_1 = 1/d, p_k = 1/(k(k-1))."""
    k = np.arange(1, d + 1, dtype=np.float64)
    p = np.empty(d)
    p[0] = 1.0 / d
    if d > 1:
        p[1:] = 1.0 / (k[1:] * (k[1:] - 1.0))
    return p / p.sum()


def robust_soliton(d: int, c: float = 0.03, delta: float = 0.5) -> np.ndarray:
    """Robust Soliton distribution (Luby, LT codes).

    rho(k) ideal soliton; tau(k) spike at d/R with R = c*ln(d/delta)*sqrt(d).
    """
    rho = ideal_soliton(d)
    R = c * np.log(d / delta) * np.sqrt(d)
    R = max(R, 1.0 + 1e-9)
    spike = int(min(max(round(d / R), 1), d))
    tau = np.zeros(d)
    ks = np.arange(1, spike, dtype=np.float64)
    if spike > 1:
        tau[: spike - 1] = R / (ks * d)
    tau[spike - 1] = R * np.log(R / delta) / d
    p = rho + tau
    return p / p.sum()


# Optimized degree distributions from Table IV of the paper (model (46)).
# Keys are mn; values are the probability masses over degrees 1..6.
TABLE_IV: dict[int, list[float]] = {
    6: [0.0217, 0.9390, 0.0393, 0.0, 0.0, 0.0],
    9: [0.0291, 0.7243, 0.2466, 0.0, 0.0, 0.0],
    12: [0.0598, 0.1639, 0.7056, 0.0707, 0.0, 0.0],
    16: [0.0264, 0.3724, 0.1960, 0.4052, 0.0, 0.0],
    25: [0.0221, 0.4725, 0.1501, 0.0, 0.0, 0.3553],
}


def optimized_distribution(d: int) -> np.ndarray:
    """Paper Table IV distribution when available, else Wave Soliton.

    For small mn the LP-optimized distributions (Section IV-C) materially
    lower the recovery threshold; for large mn Wave Soliton is asymptotically
    optimal and the LP is solved on demand via repro.core.lp_design.
    """
    if d in TABLE_IV:
        p = np.zeros(d)
        src = TABLE_IV[d][:d]
        p[: len(src)] = src
        return p / p.sum()
    return wave_soliton(d)


def average_degree(p: np.ndarray) -> float:
    k = np.arange(1, len(p) + 1, dtype=np.float64)
    return float(np.dot(k, p))


def degree_generator_poly(p: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Omega(x) = sum_k p_k x^k (paper eq. (9))."""
    x = np.asarray(x, dtype=np.float64)
    ks = np.arange(1, len(p) + 1)
    return np.sum(p[None, :] * x[..., None] ** ks[None, :], axis=-1)


def degree_generator_dpoly(p: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Omega'(x) = sum_k k p_k x^{k-1}."""
    x = np.asarray(x, dtype=np.float64)
    ks = np.arange(1, len(p) + 1)
    return np.sum(ks[None, :] * p[None, :] * x[..., None] ** (ks[None, :] - 1), axis=-1)


def sample_degrees(rng: np.random.Generator, p: np.ndarray, size: int) -> np.ndarray:
    """Draw `size` degrees in 1..len(p) from distribution p."""
    return rng.choice(np.arange(1, len(p) + 1), size=size, p=p)


DISTRIBUTIONS = {
    "wave_soliton": wave_soliton,
    "ideal_soliton": ideal_soliton,
    "robust_soliton": robust_soliton,
    "optimized": optimized_distribution,
}


def get_distribution(name: str, d: int, **kw) -> np.ndarray:
    try:
        fn = DISTRIBUTIONS[name]
    except KeyError as e:
        raise ValueError(f"unknown degree distribution {name!r}; "
                         f"options: {sorted(DISTRIBUTIONS)}") from e
    return fn(d, **kw)
