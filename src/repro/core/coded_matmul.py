"""Coded distributed matmul as a JAX/shard_map primitive.

The public entry point is ``repro.coded`` (scheme registry +
``CodedMatmulConfig`` + ``CodedOp`` plan->bind->apply; DESIGN.md section
7); this module holds the device-path machinery it stages --
``CodedMatmulPlan``/``make_plan``, tile packing, backend local-product
factories, and ``stage_coded_matmul`` -- plus the deprecated flat-kwarg
``coded_matmul`` shim.

Maps the paper's master/worker protocol onto an SPMD mesh axis:

* worker k  = device k on the ``workers`` mesh axis (N devices);
* its task  = row k of the coefficient matrix M (sampled on host, static);
* local compute = sum_{l} w_kl * A_{i_l}^T B_{j_l}, via a pluggable backend
  (registered in ``repro.core.coded_backends``);
* decode    = blocks = D @ C~  with D = pinv(M) precomputed on host, executed
  as one psum over the axis (decoding a full-rank linear code is linear, so
  on-device it collapses to a single fused contraction; the peeling/rooting
  schedule is the *host* decode used by the runtime layer).

Local-compute backends:

* ``"dense_scan"``   -- einsum over the (padded) task slots as a lax.scan:
  exactly ``max_degree`` dense block products per worker.  Cost scales with
  the dense block dims regardless of sparsity.
* ``"block_sparse"`` -- A is packed host-side into per-worker fused-gather
  tiles (``pack_worker_tiles``: tile values + source row-block/column-group
  addresses into the ORIGINAL B + per-slot weights) and the local product
  dispatches ``repro.kernels.spmm_block_fused``, which DMAs tiles straight
  out of the untouched (s, t) B.  No stacked ``B_tall`` copy is ever
  materialized, so local compute AND HBM traffic scale with the number of
  LIVE tiles -- the paper's nnz-proportional claim (Theorem 1) end-to-end
  on the device path.

Decode layout: by default the decode psum replicates the full
``(mn, br, bt)`` block tensor to every device.  With ``out_sharded=True``
the decode is a ``psum_scatter`` instead -- each device reduces only its
1/N shard of the (zero-padded to a multiple of N) block dimension, so
decode traffic is also nnz-proportional; the final block->C assembly is
left to XLA outside the shard_map and only gathers if a consumer demands
replication.

TPU adaptation notes (DESIGN.md section 3):
  - SPMD lockstep means every device pays for the *maximum* degree in the
    batch, not its own degree.  The distribution is therefore truncated at
    ``max_degree`` (default ~ 2 ln(mn), preserving decodability -- validated
    empirically in tests) and every device runs exactly max_degree padded
    slots (zero weights contribute nothing numerically).
  - Fault tolerance: ``survivors`` masks dead/straggling devices; the decode
    matrix is re-derived from the surviving rows on host (any full-rank K
    subset suffices -- Theorem 2), and dead devices' contributions are zeroed
    on device.  This is the any-K-of-N property that lets a multi-pod step
    tolerate a lost pod without recompute.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import scipy.sparse as sp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import coded_backends
from repro.core.decoder import DecodingError, decode_matrix
from repro.core.encoder import (
    SparseCodeSpec,
    chunk_slices,
    generate_coefficient_matrix,
)
from repro.kernels import ops
from repro.sparse.blocksparse import BlockELL, dense_to_block_ell

# Snapshot of the registered backend names at import time; prefer
# ``repro.core.coded_backends.backend_names()`` for an always-fresh view.
BACKENDS = coded_backends.backend_names()


def chunk_mask_progress(mask: np.ndarray, num_workers: int) -> np.ndarray:
    """(N, q) per-chunk completion mask -> (N,) completed-prefix counts.

    Sub-task streams are ordered, so only prefix-form rows (all True then
    all False) describe a physical state; a True after a False means the
    caller skipped a chunk and is rejected rather than silently reread.
    """
    mask = np.asarray(mask, dtype=bool)
    if mask.ndim != 2:
        raise ValueError(f"chunk mask must be 2-D (N, q), got shape {mask.shape}")
    if mask.shape[0] != num_workers:
        raise ValueError(
            f"chunk mask has {mask.shape[0]} rows for {num_workers} workers")
    progress = mask.sum(axis=1)
    prefix = np.take_along_axis(
        np.cumsum(mask, axis=1),
        np.maximum(progress[:, None] - 1, 0), axis=1).reshape(-1)
    bad = np.flatnonzero((progress > 0) & (prefix != progress))
    if bad.size:
        raise ValueError(
            f"chunk mask rows {bad.tolist()} are not prefix-form: ordered "
            "sub-task streams complete chunk c only after chunks 0..c-1")
    return progress.astype(np.int64)


@dataclasses.dataclass(frozen=True)
class CodedMatmulPlan:
    """Host-side static plan: tasks + decode matrix, ready to stage to device."""

    spec: SparseCodeSpec
    cols: np.ndarray      # (N, Lmax) int32 block ids, padded with 0
    weights: np.ndarray   # (N, Lmax) f32, padded with 0.0
    decode: np.ndarray    # (mn, N) f32: D s.t. blocks = D @ C~
    max_degree: int

    @property
    def m(self) -> int:
        return self.spec.m

    @property
    def n(self) -> int:
        return self.spec.n

    @property
    def num_workers(self) -> int:
        return self.spec.num_workers

    def coefficient_matrix(self) -> np.ndarray:
        """Dense M (N, mn) reconstructed from the padded task table.

        Padded slots carry weight 0.0 and contribute nothing (they land on
        block id 0 but add zero).
        """
        M = np.zeros((self.num_workers, self.m * self.n), dtype=np.float64)
        rows = np.repeat(np.arange(self.num_workers), self.cols.shape[1])
        np.add.at(M, (rows, self.cols.reshape(-1).astype(np.int64)),
                  self.weights.reshape(-1).astype(np.float64))
        return M

    def with_survivors(self, survivors: np.ndarray) -> "CodedMatmulPlan":
        """Re-derive the decode matrix using only surviving workers' rows.

        survivors: boolean mask (N,) -- worker liveness -- or (N, q) -- the
        per-chunk completion mask of the chunked protocol, dispatched to
        ``with_chunk_progress`` (a device that completed its first chunks
        contributes those slots to the decode instead of being zeroed
        wholesale).  Requires the surviving submatrix to be full column rank
        (Theorem 2 says w.h.p. it is once >= ~mn survive); raises
        ``DecodingError`` (a ValueError subclass) otherwise.
        """
        survivors = np.asarray(survivors, dtype=bool)
        if survivors.ndim == 2:
            return self.with_chunk_progress(
                chunk_mask_progress(survivors, self.num_workers),
                survivors.shape[1])
        survivors = survivors.reshape(-1)
        if survivors.shape[0] != self.num_workers:
            raise ValueError(
                f"survivors mask has {survivors.shape[0]} entries for "
                f"{self.num_workers} workers")
        if survivors.all():
            return self
        d = self.m * self.n
        M_surv = self.coefficient_matrix() * survivors[:, None]
        rank = int(np.linalg.matrix_rank(M_surv))
        if rank < d:
            raise DecodingError(
                f"only {int(survivors.sum())}/{self.num_workers} survivors: "
                f"surviving coefficient rows have rank {rank} < {d} -- cannot "
                "decode; any full-column-rank subset would do (Theorem 2)")
        D = np.linalg.pinv(M_surv)
        return dataclasses.replace(self, decode=D.astype(np.float32))

    def with_chunk_progress(
        self, progress: np.ndarray, num_chunks: int
    ) -> "CodedMatmulPlan":
        """Partial-straggler rebind: keep each worker's completed slot prefix.

        Chunk boundaries follow the SAME rule as the host task model
        (``chunk_slices`` over each worker's actual degree -- its live slots
        occupy a prefix of the padded table, padded slots carry weight 0 and
        belong to no chunk), so "device k completed chunk c" and "worker k
        completed chunk c" denote the same slots and host-observed progress
        can drive this rebind directly.  ``progress[k]`` = chunks device k
        completed; slots beyond its completed prefix get weight 0, the
        decode matrix is the pseudo-inverse of the prefix-truncated
        coefficient matrix, and the psum then sums exactly the completed
        work.  Raises ``DecodingError`` when the completed prefixes lose
        column rank.  Tile packs stay valid: they depend only on the *base*
        task table, and the block_sparse local product re-reads weights from
        the staged plan.
        """
        progress = np.asarray(progress, dtype=np.int64).reshape(-1)
        if progress.shape[0] != self.num_workers:
            raise ValueError(
                f"progress has {progress.shape[0]} entries for "
                f"{self.num_workers} workers")
        if progress.min() < 0 or progress.max() > num_chunks:
            raise ValueError(
                f"progress must lie in [0, {num_chunks}], got {progress}")
        if (progress == num_chunks).all():
            return self
        L = self.cols.shape[1]
        degrees = np.count_nonzero(self.weights, axis=1)
        keep = np.zeros((self.num_workers, L), dtype=bool)
        for k, (deg, p) in enumerate(zip(degrees, progress)):
            if p > 0:
                keep[k, :chunk_slices(int(deg), num_chunks)[p - 1].stop] = True
        weights = np.where(keep, self.weights, 0.0).astype(np.float32)
        masked = dataclasses.replace(self, weights=weights)
        d = self.m * self.n
        M_eff = masked.coefficient_matrix()
        rank = int(np.linalg.matrix_rank(M_eff))
        if rank < d:
            raise DecodingError(
                f"completed chunk prefixes (progress={progress.tolist()}, "
                f"q={num_chunks}) have rank {rank} < {d} -- cannot decode; "
                "more chunks must finish")
        D = np.linalg.pinv(M_eff)
        return dataclasses.replace(masked, decode=D.astype(np.float32))


def make_plan(
    m: int,
    n: int,
    num_workers: int,
    distribution: str = "wave_soliton",
    weight_kind: str = "symmetric",
    max_degree: int | None = None,
    seed: int = 0,
    max_resample: int = 50,
) -> CodedMatmulPlan:
    """Sample a (P,S)-sparse code and build the SPMD plan.

    The degree distribution is truncated at max_degree (lockstep SPMD pays for
    the max anyway); resamples until M is full rank (Theorem 2: succeeds
    immediately w.h.p.).
    """
    d = m * n
    max_degree = max_degree or max(1, min(d, int(np.ceil(2 * np.log(max(d, 2)) + 1))))
    for attempt in range(max_resample):
        spec = SparseCodeSpec(m=m, n=n, num_workers=num_workers,
                              distribution=distribution,
                              weight_kind=weight_kind, seed=seed + attempt)
        M = generate_coefficient_matrix(spec)
        # truncate: rows with degree > max_degree keep their first max_degree
        cols = np.zeros((num_workers, max_degree), dtype=np.int32)
        weights = np.zeros((num_workers, max_degree), dtype=np.float32)
        Mt = sp.lil_matrix((num_workers, d))
        for k in range(num_workers):
            lo, hi = M.indptr[k], M.indptr[k + 1]
            take = min(hi - lo, max_degree)
            cs = M.indices[lo:lo + take]
            ws = M.data[lo:lo + take]
            cols[k, :take] = cs
            weights[k, :take] = ws
            Mt[k, cs] = ws
        Mt = Mt.tocsr()
        if np.linalg.matrix_rank(Mt.toarray()) >= d:
            D = decode_matrix(Mt).astype(np.float32)
            return CodedMatmulPlan(spec=spec, cols=cols, weights=weights,
                                   decode=D, max_degree=max_degree)
    raise RuntimeError(f"no full-rank coefficient matrix after {max_resample} tries")


# ------------------------- local-compute backends ---------------------------

def _local_dense_scan(A, B, cols_k, w_k, m: int, n: int):
    """One worker's combination: sum_l w_l A_{i_l}^T B_{j_l} (scan over slots)."""
    s, r = A.shape
    _, t = B.shape
    br, bt = r // m, t // n

    def body(acc, slot):
        col, w = slot
        i = col // n
        j = col % n
        Ai = jax.lax.dynamic_slice(A, (0, i * br), (s, br))
        Bj = jax.lax.dynamic_slice(B, (0, j * bt), (s, bt))
        prod = jnp.einsum("sr,st->rt", Ai, Bj,
                          preferred_element_type=jnp.float32)
        return acc + w.astype(jnp.float32) * prod, None

    acc0 = jnp.zeros((br, bt), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (cols_k, w_k))
    return acc


@dataclasses.dataclass(frozen=True)
class WorkerTilePack:
    """Per-worker fused-gather tiles of the sparse operand.

    Worker k's local product sum_l w_kl A_{i_l}^T B_{j_l} runs as ONE
    fused-gather SpMM (``kernels.spmm_block_fused``): each packed tile of A
    carries the address of the B tile it multiplies -- source row-block in
    the original (s, t) B plus the source column group j_l -- and the slot's
    code weight.  Nothing of B is ever stacked or copied:

      vals : (N, br/bs, Lw, bs, bs)  live tiles, zero-padded to Lw slots
      src  : (N, br/bs, Lw, 2) int32 [row-block of B in s/bs, column group
             j in n]
      wslot: (N, br/bs, Lw) f32      the slot's code weight w_kl (0 on pads)
      slot_of: (N, br/bs, Lw) int32  originating task slot l of each tile
             (0 on pads -- gate on wslot != 0)

    Weights stay per-slot (not folded into the tile values), and the pack
    depends only on the BASE task table -- never on the decode matrix or
    the currently staged weights -- so one pack serves any survivor mask.
    ``slot_of`` is what makes that true under the chunked protocol: the
    local product gathers the *staged plan's* weight for each tile through
    it, so a chunk-masked plan (some slots zeroed by
    ``with_chunk_progress``) reuses the very same pack.

    Quantized coded compute: with ``compute_dtype`` "bfloat16" the tile
    values are stored rounded to bf16 (the kernels upcast to f32 for the
    MXU accumulate); with "int8" each tile is symmetric-quantized with its
    own scale ``amax(|tile|)/127`` recorded in ``tile_scale`` -- the scale
    is folded into the per-tile weight at staging time (the kernels never
    change), so dequantize cost is zero.  The coding weights are exact
    either way; only the operand tiles carry rounding error, which the
    config layer budgets against the scheme's ``cond_warn`` decode
    conditioning (DESIGN.md section 12).
    """

    vals: np.ndarray
    src: np.ndarray
    wslot: np.ndarray
    block_size: int
    live_tiles: np.ndarray  # (N,) total live tiles per worker (cost proxy)
    #: None only on packs from pre-chunking builders; the block_sparse
    #: factory REFUSES those (it cannot follow a chunk-masked plan's weights)
    slot_of: np.ndarray | None = None
    compute_dtype: str = "float32"
    #: (N, CBl, Lw) f32 per-tile dequant scale; None unless compute_dtype
    #: is "int8"
    tile_scale: np.ndarray | None = None


# re-export: the canonical table lives in the jax-free backend registry so
# the config layer can budget quantization without importing jax
QUANT_EPS = coded_backends.QUANT_EPS


def pack_worker_tiles(a_sparse: BlockELL, plan: CodedMatmulPlan,
                      compute_dtype: str = "float32") -> WorkerTilePack:
    """Re-stripe A's global block-ELL into per-worker fused-gather tiles.

    Fully vectorized (bucketed NumPy, no Python loop over N x L x CB):
    entries are laid out slot-major (l ascending, then the BlockELL tile
    order within the slot), the same order the old nested loops produced.

    ``compute_dtype`` quantizes the packed tile values ("bfloat16" rounds
    in place, "int8" symmetric-quantizes with a per-tile scale recorded in
    ``tile_scale``); coding weights and addresses stay exact f32/int32.
    """
    if compute_dtype not in QUANT_EPS:
        raise ValueError(
            f"compute_dtype {compute_dtype!r} not in {sorted(QUANT_EPS)}")
    s, r = a_sparse.shape
    bs = a_sparse.block_size
    m, n = plan.m, plan.n
    if r % m:
        raise ValueError(f"A cols {r} not divisible by m={m}")
    br = r // m
    if br % bs or s % bs:
        raise ValueError(
            f"block partition ({br} x {s}) not divisible by block_size {bs}")
    CBl = br // bs            # column blocks per worker output row-block
    N, L = plan.cols.shape

    live_slot = plan.weights != 0.0                     # (N, L)
    i_blk = (plan.cols // n).astype(np.int64)           # (N, L) source A column group
    j_blk = (plan.cols % n).astype(np.int32)            # (N, L) source B column group
    # global BlockELL stripe feeding (k, l, cb):  g = i * CBl + cb
    g = i_blk[:, :, None] * CBl + np.arange(CBl)[None, None, :]   # (N, L, CBl)
    cnt = np.where(live_slot[:, :, None], a_sparse.nnzb[g], 0)    # (N, L, CBl)
    per_kcb = cnt.transpose(0, 2, 1)                    # (N, CBl, L)
    Lw = max(1, int(per_kcb.sum(axis=-1).max(initial=0)))
    # destination slot of each stripe's first tile: exclusive cumsum over l
    off = np.cumsum(per_kcb, axis=-1) - per_kcb         # (N, CBl, L)

    E = a_sparse.slots
    valid = np.arange(E)[None, None, None, :] < per_kcb[..., None]  # (N,CBl,L,E)
    kk, cc, ll, ee = np.nonzero(valid)
    gg = g[kk, ll, cc]
    dst = off[kk, cc, ll] + ee

    vals = np.zeros((N, CBl, Lw, bs, bs), dtype=np.float32)
    src = np.zeros((N, CBl, Lw, 2), dtype=np.int32)
    wslot = np.zeros((N, CBl, Lw), dtype=np.float32)
    slot_of = np.zeros((N, CBl, Lw), dtype=np.int32)
    vals[kk, cc, dst] = a_sparse.vals[gg, ee]
    src[kk, cc, dst, 0] = a_sparse.idx[gg, ee]
    src[kk, cc, dst, 1] = j_blk[kk, ll]
    wslot[kk, cc, dst] = plan.weights[kk, ll]
    slot_of[kk, cc, dst] = ll
    live = per_kcb.sum(axis=(1, 2)).astype(np.int64)

    tile_scale = None
    if compute_dtype == "bfloat16":
        vals = vals.astype(ml_dtypes.bfloat16)
    elif compute_dtype == "int8":
        amax = np.abs(vals).max(axis=(-2, -1))              # (N, CBl, Lw)
        tile_scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
        vals = np.rint(vals / tile_scale[..., None, None]).astype(np.int8)
    return WorkerTilePack(vals=vals, src=src, wslot=wslot, block_size=bs,
                          live_tiles=live, slot_of=slot_of,
                          compute_dtype=compute_dtype, tile_scale=tile_scale)


# ------------------------------- entry point --------------------------------

def _largest_tile(bt: int, cap: int = 128) -> int:
    """Largest divisor of bt that is <= cap (tile width for the kernel grid).

    Falling back to the whole row (bt) only when bt itself is <= cap or
    prime beyond it -- never a degenerate full-width tile when a proper
    divisor exists.
    """
    for d in range(min(bt, cap), 0, -1):
        if bt % d == 0:
            return d
    return 1


def _plan_t_tiling(bt: int, cap: int = 128) -> tuple[int, int]:
    """(t_tile, bt_pad) for the kernel grid over a bt-wide column group.

    A ``bt`` whose only divisors <= cap are tiny (prime bt, or 2 * prime
    beyond the cap) used to silently degrade toward t_tile=1 -- a
    grid-per-element launch.  Instead the column group is zero-padded up to
    the next multiple of 8 (the VPU sublane) that tiles well, and the
    caller slices the pad columns back off; zero columns contribute
    nothing, so the kept columns are bitwise unchanged.
    """
    t_tile = _largest_tile(bt, cap)
    if t_tile >= min(bt, 8):
        return t_tile, bt
    bt_pad = -(-bt // 8) * 8
    return _largest_tile(bt_pad, cap), bt_pad


def _make_dense_scan_local_product(plan: CodedMatmulPlan, pack, bt: int):
    cols_t = jnp.asarray(plan.cols)        # (N, L)
    w_t = jnp.asarray(plan.weights)        # (N, L)
    m, n = plan.m, plan.n

    def local_product(k, A_, B_):
        return _local_dense_scan(A_, B_, cols_t[k], w_t[k], m, n)

    return local_product


def _block_sparse_operands(plan: CodedMatmulPlan, pack: WorkerTilePack,
                           bt: int):
    """Shared staging of the block_sparse factories: device-resident pack
    arrays, the slot-weight gather, and the (t_tile, bt_pad) grid plan."""
    vals_t = jnp.asarray(pack.vals)    # (N, CBl, Lw, bs, bs)
    src_t = jnp.asarray(pack.src)      # (N, CBl, Lw, 2)
    t_tile, bt_pad = _plan_t_tiling(bt)
    if pack.slot_of is None:
        # a pack without the tile->slot map cannot follow a chunk-masked
        # plan's weights; computing with its baked-in base weights would be
        # silently wrong under with_chunk_progress, so refuse outright
        raise ValueError(
            "WorkerTilePack has no slot_of map (built by a pre-chunking "
            "packer?); rebuild it with pack_worker_tiles")
    # The pack carries the BASE task table's weights; the staged plan may
    # have zeroed some (chunk-prefix masking).  Re-read each live tile's
    # weight from the *current* plan through slot_of so one pack serves
    # every chunk-progress rebind; for an unmasked plan this reproduces
    # pack.wslot bit-for-bit (same f32 values, gathered instead of copied).
    w_cur = jnp.asarray(plan.weights)                    # (N, L)
    sl_t = jnp.asarray(pack.slot_of)                     # (N, CBl, Lw)
    live_t = jnp.asarray(pack.wslot != 0.0)
    N_ = plan.weights.shape[0]
    wsl_all = jnp.where(
        live_t, w_cur[jnp.arange(N_)[:, None, None], sl_t], 0.0)
    if pack.tile_scale is not None:
        # int8 pack: fold the per-tile dequant scale into the per-tile
        # weight -- w * (scale * tile_q) == (w * scale) * tile_q, and the
        # kernels already multiply by the weight, so dequantize is free
        wsl_all = wsl_all * jnp.asarray(pack.tile_scale)

    def pad_cols(B_):
        # zero-pad each bt-wide column group up to bt_pad (no-op pass-through
        # when bt tiles fine); the kernel output is sliced back below
        if bt_pad == bt:
            return B_
        s_, t_ = B_.shape
        return jnp.pad(
            B_.reshape(s_, t_ // bt, bt),
            ((0, 0), (0, 0), (0, bt_pad - bt))).reshape(s_, -1)

    return vals_t, src_t, wsl_all, t_tile, bt_pad, pad_cols


def _make_block_sparse_local_product(plan: CodedMatmulPlan, pack: WorkerTilePack,
                                     bt: int):
    vals_t, src_t, wsl_all, t_tile, bt_pad, pad_cols = _block_sparse_operands(
        plan, pack, bt)

    def local_product(k, A_, B_):
        # fused gather: tiles address the original B directly -- no
        # stacked (max_degree * s, bt) copy is ever materialized
        out = ops.spmm_block_fused(vals_t[k], src_t[k], wsl_all[k],
                                   pad_cols(B_), bt=bt_pad, t_tile=t_tile)
        return out[:, :bt] if bt_pad != bt else out

    return local_product


def _make_block_sparse_fused_decode(plan: CodedMatmulPlan, pack: WorkerTilePack,
                                    bt: int):
    """The one-launch local product: decode combine fused into the epilogue.

    Returns ``(k, A, B, dvec) -> (mn, br, bt)`` where dvec is this worker's
    survivor decode column ``D[:, k] * alive_k``; the output is already the
    stack of decode-weighted copies, ready for the psum -- the separate
    ``D @ C~`` contraction never exists in the staged program.
    """
    vals_t, src_t, wsl_all, t_tile, bt_pad, pad_cols = _block_sparse_operands(
        plan, pack, bt)

    def local_product_decode(k, A_, B_, dvec):
        out = ops.spmm_block_fused_decode(
            vals_t[k], src_t[k], wsl_all[k], dvec, pad_cols(B_),
            bt=bt_pad, t_tile=t_tile)
        return out[:, :, :bt] if bt_pad != bt else out

    return local_product_decode


coded_backends.get_backend("dense_scan").local_product_factory = (
    _make_dense_scan_local_product)
coded_backends.get_backend("block_sparse").local_product_factory = (
    _make_block_sparse_local_product)
coded_backends.get_backend("block_sparse").fused_local_product_factory = (
    _make_block_sparse_fused_decode)


def _check_operands(A, B, plan: CodedMatmulPlan, mesh, axis_name: str):
    """Shared shape/mesh validation; returns (N, s, r, t, br, bt)."""
    N = mesh.shape[axis_name]
    if N != plan.num_workers:
        raise ValueError(f"mesh axis {axis_name}={N} != plan workers {plan.num_workers}")
    m, n = plan.m, plan.n
    s, r = A.shape
    _, t = B.shape
    if r % m or t % n:
        raise ValueError(f"A cols {r} % m={m} or B cols {t} % n={n} nonzero")
    return N, s, r, t, r // m, t // n


def resolve_pack(
    A,
    plan: CodedMatmulPlan,
    *,
    pack: WorkerTilePack | None = None,
    a_sparse: BlockELL | None = None,
    block_size: int = 8,
    compute_dtype: str = "float32",
    num_workers: int,
    s: int,
    r: int,
    br: int,
) -> WorkerTilePack:
    """Obtain-and-validate the worker tile pack for the block_sparse backend.

    Accepts a prebuilt ``pack`` (e.g. from the runtime pack cache), an
    ``a_sparse`` host BlockELL of A (packed here), or a concrete A (packed
    with ``block_size``).  A pack built against different operands silently
    gathers garbage (XLA clamps out-of-range indices), so the result is
    always validated against the operand geometry before use -- including
    its ``compute_dtype``: a pack quantized differently than the config
    asked for computes subtly different numbers.
    """
    n = plan.n
    if pack is None:
        if a_sparse is None and isinstance(A, jax.core.Tracer):
            raise ValueError(
                "backend='block_sparse' under jit needs a_sparse= (a host "
                "BlockELL) or pack= (a WorkerTilePack): the tile pack is "
                "static metadata and cannot be derived from a traced "
                "operand")
        ell = a_sparse if a_sparse is not None else dense_to_block_ell(
            np.asarray(A, dtype=np.float32), block_size=block_size)
        if ell.shape != (s, r):
            raise ValueError(f"a_sparse shape {ell.shape} != A shape {(s, r)}")
        pack = pack_worker_tiles(ell, plan, compute_dtype=compute_dtype)
    if getattr(pack, "compute_dtype", "float32") != compute_dtype:
        raise ValueError(
            f"pack was quantized as {pack.compute_dtype!r} but the config "
            f"asks for compute_dtype={compute_dtype!r}; rebuild the pack")
    if pack.vals.shape[0] != num_workers:
        raise ValueError(
            f"pack built for {pack.vals.shape[0]} workers, mesh has {num_workers}")
    # a pack built against different operands silently gathers garbage
    # (XLA clamps out-of-range indices), so validate it against (s, r)
    bs_p = pack.block_size
    if s % bs_p or pack.vals.shape[1] * bs_p != br:
        raise ValueError(
            f"pack (block_size={bs_p}, {pack.vals.shape[1]} column "
            f"blocks) does not tile operands with s={s}, br={br}")
    if int(pack.src[..., 0].max(initial=0)) >= s // bs_p:
        raise ValueError(
            f"pack row-block indices exceed s//bs={s // bs_p}: the pack "
            "was built for a different A")
    if int(pack.src[..., 1].max(initial=0)) >= n:
        raise ValueError(
            f"pack column-group indices exceed n={n}: the pack was "
            "built for a different plan")
    return pack


def stage_coded_matmul(
    A: jax.Array,
    B: jax.Array,
    plan: CodedMatmulPlan,
    mesh: jax.sharding.Mesh,
    *,
    axis_name: str = "model",
    alive: np.ndarray | None = None,
    out_dtype=jnp.float32,
    backend: str = "dense_scan",
    pack: WorkerTilePack | None = None,
    out_sharded: bool = False,
) -> jax.Array:
    """Stage the shard_map program for one coded matmul (the shared core).

    ``plan`` must already be survivor-adjusted (its decode matrix re-derived
    via ``with_survivors``) and ``alive`` is the matching worker-liveness
    mask (None = all alive).  For backends with ``needs_pack``, ``pack``
    must be pre-resolved (``resolve_pack``).  Both the legacy
    ``coded_matmul`` shim and ``repro.coded.CodedOp`` funnel through here,
    which is what makes old-vs-new bit-parity structural rather than
    coincidental.
    """
    entry = coded_backends.get_backend(backend)
    if entry.virtual:
        raise ValueError(
            f"backend {backend!r} is a dispatch pseudo-backend: resolve it "
            "to a concrete backend (CodedOp does this) before staging")
    N, s, r, t, br, bt = _check_operands(A, B, plan, mesh, axis_name)
    m, n = plan.m, plan.n

    if alive is None:
        alive_t = jnp.ones((N,), jnp.float32)
    else:
        alive_t = jnp.asarray(alive, dtype=jnp.float32)

    D_t = jnp.asarray(plan.decode)         # (mn, N)
    if entry.needs_pack and pack is None:
        raise ValueError(
            f"backend {backend!r} needs a resolved WorkerTilePack "
            "(see resolve_pack)")
    if entry.local_product_factory is None:
        raise ValueError(
            f"backend {backend!r} is registered but has no "
            "local_product_factory attached")
    fuse = entry.fused_decode and entry.fused_local_product_factory is not None
    if fuse:
        local_product_decode = entry.fused_local_product_factory(plan, pack, bt)
    else:
        local_product = entry.local_product_factory(plan, pack, bt)

    mn = m * n
    mn_pad = -(-mn // N) * N  # scatter splits the block dim N ways

    def worker_fn(A_, B_):
        k = jax.lax.axis_index(axis_name)
        if fuse:
            # one-launch path: the decode combine happens in the kernel
            # epilogue, so the (mn, br, bt) contribution comes out of the
            # local product directly -- no D @ C~ contraction is staged
            contrib = local_product_decode(k, A_, B_, D_t[:, k] * alive_t[k])
        else:
            Ct = local_product(k, A_, B_)
            # decode contribution: blocks_c += D[c, k] * C~_k (zeroed if dead)
            contrib = (D_t[:, k] * alive_t[k])[:, None, None] * Ct[None]
        if out_sharded:
            contrib = jnp.pad(contrib, ((0, mn_pad - mn), (0, 0), (0, 0)))
            # each device reduces only its 1/N shard of the block dim
            return compat.psum_scatter(contrib, axis_name,
                                       scatter_dimension=0, tiled=True)
        blocks = jax.lax.psum(contrib, axis_name)          # (mn, br, bt)
        C = blocks.reshape(m, n, br, bt).transpose(0, 2, 1, 3).reshape(m * br, n * bt)
        return C.astype(out_dtype)

    fn = compat.shard_map(
        worker_fn, mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(axis_name) if out_sharded else P(),
        check_vma=False,
    )
    if not out_sharded:
        return fn(A, B)
    blocks = fn(A, B)                                      # (mn_pad, br, bt)
    C = blocks[:mn].reshape(m, n, br, bt).transpose(0, 2, 1, 3)
    return C.reshape(m * br, n * bt).astype(out_dtype)


def _coded_matmul(
    A: jax.Array,
    B: jax.Array,
    plan: CodedMatmulPlan,
    mesh: jax.sharding.Mesh,
    axis_name: str = "model",
    survivors: np.ndarray | None = None,
    out_dtype=jnp.float32,
    backend: str = "dense_scan",
    a_sparse: BlockELL | None = None,
    block_size: int = 8,
    pack: WorkerTilePack | None = None,
    out_sharded: bool = False,
) -> jax.Array:
    """Flat-kwarg implementation behind the deprecated ``coded_matmul`` shim."""
    coded_backends.get_backend(backend)  # raises "backend ... not in" early
    N, s, r, t, br, bt = _check_operands(A, B, plan, mesh, axis_name)

    alive = None
    if survivors is not None:
        surv = np.asarray(survivors, dtype=bool)
        plan = plan.with_survivors(surv)
        # per-chunk masks collapse to worker liveness for the psum gate --
        # the slot-level masking already lives in the rebuilt plan weights
        alive = (chunk_mask_progress(surv, N) > 0) if surv.ndim == 2 else surv

    if coded_backends.get_backend(backend).needs_pack:
        pack = resolve_pack(A, plan, pack=pack, a_sparse=a_sparse,
                            block_size=block_size, num_workers=N,
                            s=s, r=r, br=br)
    return stage_coded_matmul(A, B, plan, mesh, axis_name=axis_name,
                              alive=alive, out_dtype=out_dtype,
                              backend=backend, pack=pack,
                              out_sharded=out_sharded)


def coded_matmul(
    A: jax.Array,
    B: jax.Array,
    plan: CodedMatmulPlan,
    mesh: jax.sharding.Mesh,
    axis_name: str = "model",
    survivors: np.ndarray | None = None,
    out_dtype=jnp.float32,
    backend: str = "dense_scan",
    a_sparse: BlockELL | None = None,
    block_size: int = 8,
    pack: WorkerTilePack | None = None,
    out_sharded: bool = False,
) -> jax.Array:
    """DEPRECATED flat-kwarg entry point; use ``repro.coded`` instead.

    C = A^T B computed with the (P,S)-sparse code over a mesh axis.
    A: (s, r), B: (s, t), replicated over `axis_name` (the worker axis).
    Returns C (r, t).  r % m == 0, t % n == 0 required, and the mesh axis
    size must equal plan.num_workers.

    The replacement is the plan->bind->apply object API::

        from repro.coded import CodedMatmulConfig, from_plan
        op = from_plan(CodedMatmulConfig(backend=..., out_sharded=...),
                       plan).bind(mesh)
        C = op(A, B)                     # bit-identical to this function

    This shim stays bit-identical to the new API (both funnel through
    ``stage_coded_matmul``; parity is test-enforced) and will be removed
    after one deprecation cycle.  See DESIGN.md section 7 for the API and
    deprecation policy.
    """
    warnings.warn(
        "coded_matmul(...) is deprecated: use repro.coded "
        "(CodedMatmulConfig + plan/from_plan -> bind -> apply)",
        DeprecationWarning, stacklevel=2)
    return _coded_matmul(A, B, plan, mesh, axis_name=axis_name,
                         survivors=survivors, out_dtype=out_dtype,
                         backend=backend, a_sparse=a_sparse,
                         block_size=block_size, pack=pack,
                         out_sharded=out_sharded)


def uncoded_matmul_reference(A, B):
    """The plain product, for tests and overhead comparisons."""
    return jnp.einsum("sr,st->rt", A, B, preferred_element_type=jnp.float32)
