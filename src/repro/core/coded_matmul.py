"""Coded distributed matmul as a JAX/shard_map primitive.

Maps the paper's master/worker protocol onto an SPMD mesh axis:

* worker k  = device k on the ``workers`` mesh axis (N devices);
* its task  = row k of the coefficient matrix M (sampled on host, static);
* local compute = sum_{l} w_kl * A_{i_l}^T B_{j_l}, evaluated as a
  lax.scan over the (padded) task slots -- exactly `degree` block products;
* decode    = blocks = D @ C~  with D = pinv(M) precomputed on host, executed
  as one psum over the axis (decoding a full-rank linear code is linear, so
  on-device it collapses to a single fused contraction; the peeling/rooting
  schedule is the *host* decode used by the runtime layer).

TPU adaptation notes (DESIGN.md section 3):
  - SPMD lockstep means every device pays for the *maximum* degree in the
    batch, not its own degree.  The distribution is therefore truncated at
    ``max_degree`` (default ~ 2 ln(mn), preserving decodability -- validated
    empirically in tests) and every device runs exactly max_degree padded
    slots (zero weights contribute nothing numerically).
  - Fault tolerance: ``survivors`` masks dead/straggling devices; the decode
    matrix is re-derived from the surviving rows on host (any full-rank K
    subset suffices -- Theorem 2), and dead devices' contributions are zeroed
    on device.  This is the any-K-of-N property that lets a multi-pod step
    tolerate a lost pod without recompute.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from jax.sharding import PartitionSpec as P

from repro.core.decoder import decode_matrix
from repro.core.encoder import SparseCodeSpec, generate_coefficient_matrix


@dataclasses.dataclass(frozen=True)
class CodedMatmulPlan:
    """Host-side static plan: tasks + decode matrix, ready to stage to device."""

    spec: SparseCodeSpec
    cols: np.ndarray      # (N, Lmax) int32 block ids, padded with 0
    weights: np.ndarray   # (N, Lmax) f32, padded with 0.0
    decode: np.ndarray    # (mn, N) f32: D s.t. blocks = D @ C~
    max_degree: int

    @property
    def m(self) -> int:
        return self.spec.m

    @property
    def n(self) -> int:
        return self.spec.n

    @property
    def num_workers(self) -> int:
        return self.spec.num_workers

    def with_survivors(self, survivors: np.ndarray) -> "CodedMatmulPlan":
        """Re-derive the decode matrix using only surviving workers' rows.

        survivors: boolean mask (N,).  Requires the surviving submatrix to be
        full column rank (Theorem 2 says w.h.p. it is once >= ~mn survive).
        """
        M = np.zeros((self.num_workers, self.m * self.n))
        for k in range(self.num_workers):
            for l in range(self.max_degree):
                if self.weights[k, l] != 0.0:
                    M[k, self.cols[k, l]] += self.weights[k, l]
        M_surv = M * survivors[:, None]
        if np.linalg.matrix_rank(M_surv) < self.m * self.n:
            raise ValueError(
                f"only {int(survivors.sum())}/{self.num_workers} survivors; "
                "coefficient matrix lost full rank -- cannot decode")
        D = np.linalg.pinv(M_surv)
        return dataclasses.replace(self, decode=D.astype(np.float32))


def make_plan(
    m: int,
    n: int,
    num_workers: int,
    distribution: str = "wave_soliton",
    weight_kind: str = "symmetric",
    max_degree: int | None = None,
    seed: int = 0,
    max_resample: int = 50,
) -> CodedMatmulPlan:
    """Sample a (P,S)-sparse code and build the SPMD plan.

    The degree distribution is truncated at max_degree (lockstep SPMD pays for
    the max anyway); resamples until M is full rank (Theorem 2: succeeds
    immediately w.h.p.).
    """
    d = m * n
    max_degree = max_degree or max(1, min(d, int(np.ceil(2 * np.log(max(d, 2)) + 1))))
    for attempt in range(max_resample):
        spec = SparseCodeSpec(m=m, n=n, num_workers=num_workers,
                              distribution=distribution,
                              weight_kind=weight_kind, seed=seed + attempt)
        M = generate_coefficient_matrix(spec)
        # truncate: rows with degree > max_degree keep their first max_degree
        cols = np.zeros((num_workers, max_degree), dtype=np.int32)
        weights = np.zeros((num_workers, max_degree), dtype=np.float32)
        Mt = sp.lil_matrix((num_workers, d))
        for k in range(num_workers):
            lo, hi = M.indptr[k], M.indptr[k + 1]
            take = min(hi - lo, max_degree)
            cs = M.indices[lo:lo + take]
            ws = M.data[lo:lo + take]
            cols[k, :take] = cs
            weights[k, :take] = ws
            Mt[k, cs] = ws
        Mt = Mt.tocsr()
        if np.linalg.matrix_rank(Mt.toarray()) >= d:
            D = decode_matrix(Mt).astype(np.float32)
            return CodedMatmulPlan(spec=spec, cols=cols, weights=weights,
                                   decode=D, max_degree=max_degree)
    raise RuntimeError(f"no full-rank coefficient matrix after {max_resample} tries")


def _local_coded_product(A, B, cols_k, w_k, m: int, n: int):
    """One worker's combination: sum_l w_l A_{i_l}^T B_{j_l} (scan over slots)."""
    s, r = A.shape
    _, t = B.shape
    br, bt = r // m, t // n

    def body(acc, slot):
        col, w = slot
        i = col // n
        j = col % n
        Ai = jax.lax.dynamic_slice(A, (0, i * br), (s, br))
        Bj = jax.lax.dynamic_slice(B, (0, j * bt), (s, bt))
        prod = jnp.einsum("sr,st->rt", Ai, Bj,
                          preferred_element_type=jnp.float32)
        return acc + w.astype(jnp.float32) * prod, None

    acc0 = jnp.zeros((br, bt), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (cols_k, w_k))
    return acc


def coded_matmul(
    A: jax.Array,
    B: jax.Array,
    plan: CodedMatmulPlan,
    mesh: jax.sharding.Mesh,
    axis_name: str = "model",
    survivors: np.ndarray | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """C = A^T B computed with the (P,S)-sparse code over a mesh axis.

    A: (s, r), B: (s, t), replicated over `axis_name` (the worker axis).
    Returns C (r, t) replicated.  r % m == 0, t % n == 0 required, and the
    mesh axis size must equal plan.num_workers.
    """
    N = mesh.shape[axis_name]
    if N != plan.num_workers:
        raise ValueError(f"mesh axis {axis_name}={N} != plan workers {plan.num_workers}")
    if survivors is not None:
        plan = plan.with_survivors(np.asarray(survivors, dtype=bool))
        alive = jnp.asarray(survivors, dtype=jnp.float32)
    else:
        alive = jnp.ones((N,), jnp.float32)

    m, n = plan.m, plan.n
    cols_t = jnp.asarray(plan.cols)        # (N, L)
    w_t = jnp.asarray(plan.weights)        # (N, L)
    D_t = jnp.asarray(plan.decode)         # (mn, N)

    def worker_fn(A_, B_):
        k = jax.lax.axis_index(axis_name)
        Ct = _local_coded_product(A_, B_, cols_t[k], w_t[k], m, n)
        # decode contribution: blocks_c += D[c, k] * C~_k  (zeroed if dead)
        contrib = (D_t[:, k] * alive[k])[:, None, None] * Ct[None]
        blocks = jax.lax.psum(contrib, axis_name)          # (mn, br, bt)
        br, bt = Ct.shape
        C = blocks.reshape(m, n, br, bt).transpose(0, 2, 1, 3).reshape(m * br, n * bt)
        return C.astype(out_dtype)

    other_axes = tuple(a for a in mesh.axis_names if a != axis_name)
    fn = jax.shard_map(
        worker_fn, mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(),
        check_vma=False,
    )
    return fn(A, B)


def uncoded_matmul_reference(A, B):
    """The plain product, for tests and overhead comparisons."""
    return jnp.einsum("sr,st->rt", A, B, preferred_element_type=jnp.float32)
