"""Registry of local-compute backends for the coded matmul device path.

This module is deliberately jax-free: ``repro.configs`` validates
``ArchConfig`` coded settings against it at import time, and the config
layer must stay importable before XLA_FLAGS are set (the subprocess
isolation rule the SPMD checks rely on).

A backend is the strategy one worker uses to evaluate its coded
combination ``sum_l w_kl A_{i_l}^T B_{j_l}`` on device.  The entry here
carries the *metadata* the API layer needs for dispatch and validation;
the staging function itself lives in ``repro.core.coded_matmul`` (which
imports jax) and attaches when that module loads.  Registering a new
backend therefore automatically:

* makes it a legal value for ``CodedMatmulConfig.backend`` and
  ``ArchConfig.coded_backend`` (no hardcoded tuples to desync), and
* routes ``CodedOp`` dispatch once a ``local_product_factory`` is attached.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass
class Backend:
    """One registered local-compute strategy.

    needs_pack: whether the backend consumes host-side static pack metadata
    (a ``WorkerTilePack``) that must be built outside jit.
    local_product_factory: attached by the implementing module; called as
    ``factory(plan, pack, bt) -> (k, A, B) -> (br, bt)`` at staging time.
    """

    name: str
    needs_pack: bool = False
    doc: str = ""
    local_product_factory: Optional[Callable] = None


_REGISTRY: dict[str, Backend] = {}


def register_backend(name: str, *, needs_pack: bool = False, doc: str = "") -> Backend:
    """Register (or return the existing entry for) a backend name."""
    if name in _REGISTRY:
        return _REGISTRY[name]
    entry = Backend(name=name, needs_pack=needs_pack, doc=doc)
    _REGISTRY[name] = entry
    return entry


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"backend {name!r} not in {backend_names()}") from None


def backend_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def validate_backend(name: str) -> str:
    get_backend(name)
    return name


# The two built-in strategies (module docstrings in core.coded_matmul):
register_backend(
    "dense_scan",
    doc="lax.scan of dense einsum block products over the padded task slots",
)
register_backend(
    "block_sparse", needs_pack=True,
    doc="fused-gather Pallas SpMM over per-worker packed tiles of A "
        "(compute and HBM traffic scale with live tiles)",
)
