"""Registry of local-compute backends for the coded matmul device path.

This module is deliberately jax-free: ``repro.configs`` validates
``ArchConfig`` coded settings against it at import time, and the config
layer must stay importable before XLA_FLAGS are set (the subprocess
isolation rule the SPMD checks rely on).

A backend is the strategy one worker uses to evaluate its coded
combination ``sum_l w_kl A_{i_l}^T B_{j_l}`` on device.  The entry here
carries the *metadata* the API layer needs for dispatch and validation;
the staging function itself lives in ``repro.core.coded_matmul`` (which
imports jax) and attaches when that module loads.  Registering a new
backend therefore automatically:

* makes it a legal value for ``CodedMatmulConfig.backend`` and
  ``ArchConfig.coded_backend`` (no hardcoded tuples to desync), and
* routes ``CodedOp`` dispatch once a ``local_product_factory`` is attached.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass
class Backend:
    """One registered local-compute strategy.

    needs_pack: whether the backend consumes host-side static pack metadata
    (a ``WorkerTilePack``) that must be built outside jit.
    local_product_factory: attached by the implementing module; called as
    ``factory(plan, pack, bt) -> (k, A, B) -> (br, bt)`` at staging time.
    fused_decode: the backend can fold the decode combine into its local
    product's epilogue -- staging then calls ``fused_local_product_factory``
    (``factory(plan, pack, bt) -> (k, A, B, dvec) -> (mn, br, bt)``) and the
    separate ``D @ C~`` contraction never appears in the staged program.
    virtual: a dispatch pseudo-backend (e.g. ``"auto"``) that the API layer
    resolves to a concrete backend before staging; staging itself rejects it.
    """

    name: str
    needs_pack: bool = False
    doc: str = ""
    local_product_factory: Optional[Callable] = None
    fused_decode: bool = False
    fused_local_product_factory: Optional[Callable] = None
    virtual: bool = False


#: tile dtypes the pack layer can quantize coded compute to, with their
#: worst-case RELATIVE per-element rounding error.  The config layer
#: multiplies this by the scheme's declared decode conditioning
#: (``cond_warn``) to accept or reject the pairing (DESIGN.md section 12);
#: the pack layer (``pack_worker_tiles``) implements the quantization.
QUANT_EPS = {
    "float32": 0.0,
    "bfloat16": 2.0 ** -8,   # 8 mantissa bits
    "int8": 1.0 / 127.0,     # symmetric per-tile amax/127 grid
}

#: eps * cond_warn above this and decode may amplify tile rounding error
#: past usable precision -- the config constructor rejects the pairing
QUANT_COND_BUDGET = 1.0e6


_REGISTRY: dict[str, Backend] = {}


def register_backend(name: str, *, needs_pack: bool = False, doc: str = "",
                     fused_decode: bool = False,
                     virtual: bool = False) -> Backend:
    """Register (or return the existing entry for) a backend name."""
    if name in _REGISTRY:
        return _REGISTRY[name]
    entry = Backend(name=name, needs_pack=needs_pack, doc=doc,
                    fused_decode=fused_decode, virtual=virtual)
    _REGISTRY[name] = entry
    return entry


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"backend {name!r} not in {backend_names()}") from None


def backend_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def validate_backend(name: str) -> str:
    get_backend(name)
    return name


# The two built-in strategies (module docstrings in core.coded_matmul):
register_backend(
    "dense_scan",
    doc="lax.scan of dense einsum block products over the padded task slots",
)
register_backend(
    "block_sparse", needs_pack=True, fused_decode=True,
    doc="fused-gather Pallas SpMM over per-worker packed tiles of A "
        "(compute and HBM traffic scale with live tiles); the decode "
        "combine rides in the kernel epilogue -- one launch, no D @ C~",
)
register_backend(
    "auto", needs_pack=True, virtual=True,
    doc="density-keyed dispatch: measures the operand's BlockELL live-tile "
        "fraction and picks block_sparse below the configured threshold, "
        "dense_scan above it (resolved by CodedOp before staging)",
)
