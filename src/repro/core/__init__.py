"""The paper's contribution: (P, S)-sparse codes for distributed matmul."""

from repro.core.degree import (
    wave_soliton,
    robust_soliton,
    ideal_soliton,
    optimized_distribution,
    sample_degrees,
    average_degree,
)
from repro.core.encoder import (
    SparseCodeSpec,
    CodedTask,
    generate_coefficient_matrix,
    make_tasks,
    encode_blocks,
    block_col,
    col_block,
    chunk_slices,
    chunk_expand,
)
from repro.core.decoder import (
    DecodeStats,
    IncrementalRankTracker,
    peel_schedule,
    hybrid_decode,
    gaussian_decode,
    apply_schedule,
)
from repro.core.matching import perfect_matching_prob, degree_evolution
from repro.core.lp_design import optimize_degree_distribution
