"""Hybrid peeling + rooting decoder (paper Algorithm 1, Lemma 1).

The decoder is expressed in two phases:

1. ``peel_schedule(M)`` -- *structural* decoding.  The peel/root order depends
   only on the coefficient matrix M, never on the data blocks.  We therefore
   run Algorithm 1 once over M's sparsity pattern and emit a static schedule
   of ops:

     ("peel", row, col, scale)          block[col] = scale * R[row]
     ("root", col, rows, coeffs)        block[col] = sum_r coeffs * R[rows]
     ("axpy", row, col, weight)         R[row] -= weight * block[col]

2. ``apply_schedule(schedule, results)`` -- replays the schedule on the data.
   Each op is a sparse AXPY costing O(nnz(block)), so total decode cost is
   O(#axpys * nnz-per-block) = O(nnz(C) * ln(mn)) under Wave Soliton -- the
   paper's Theorem 1.  Blocks may be numpy arrays or scipy.sparse matrices.

This split is also the TPU adaptation (DESIGN.md section 3): the schedule is
computed on the host master; on device the whole decode collapses to a small
linear combine ``blocks = D @ results`` with D = pinv(M) (``decode_matrix``),
because decoding any full-rank linear code is itself linear.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp


class DecodingError(RuntimeError, ValueError):
    """Collected results cannot be decoded (rank-deficient coefficient rows).

    Subclasses both RuntimeError (historical) and ValueError so callers that
    treat rank loss as bad input -- e.g. ``CodedMatmulPlan.with_survivors``
    validation -- catch it either way.
    """


class IncrementalRankTracker:
    """Rank of a growing row set, maintained incrementally per arrival.

    The master's event loop used to recompute ``matrix_rank`` of the full
    collected submatrix on every arrival -- O(arrivals * rows * mn^2), the
    loop's hot spot once tasks are chunk-granular (q x more events).  This
    tracker keeps an orthonormal basis of the collected row space and updates
    it per arrival with one modified-Gram-Schmidt pass (re-orthogonalized
    twice for float robustness): O(mn * rank) per ``add``, so a whole job is
    O(arrivals * mn * rank) instead.

    Float caveat: rank decisions near the tolerance can disagree with an
    exact check, so callers treating ``is_full`` as a decode gate should
    confirm once with the exact test when it first fires (the executor
    does) -- the tracker's job is to make the *per-event* check cheap, not
    to be the final authority.
    """

    def __init__(self, dim: int, tol: float = 1e-10):
        self.dim = int(dim)
        self.tol = float(tol)
        self.rank = 0
        self.rows_seen = 0  # rows folded in (feeds ExecutionReport.decode_stats)
        self._Q = np.zeros((self.dim, self.dim))  # rows 0..rank-1: the basis

    @property
    def is_full(self) -> bool:
        return self.rank >= self.dim

    def add(self, row: np.ndarray) -> bool:
        """Fold one row in; returns True iff it increased the rank."""
        self.rows_seen += 1
        if self.is_full:
            return False
        v = np.asarray(
            row.toarray() if sp.issparse(row) else row, dtype=np.float64
        ).reshape(-1)
        if v.shape[0] != self.dim:
            raise ValueError(f"row has {v.shape[0]} entries, tracker dim {self.dim}")
        nv = np.linalg.norm(v)
        if nv == 0.0 or not np.isfinite(nv):
            return False
        v = v / nv
        Q = self._Q[: self.rank]
        for _ in range(2):  # classic Gram-Schmidt with one re-orthogonalization
            v = v - Q.T @ (Q @ v)
        res = np.linalg.norm(v)
        if res <= self.tol:
            return False
        self._Q[self.rank] = v / res
        self.rank += 1
        return True


@dataclasses.dataclass
class DecodeStats:
    peels: int = 0
    roots: int = 0
    axpys: int = 0
    root_row_combines: int = 0  # rows combined across all rooting steps

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _adjacency(M: sp.spmatrix):
    """Row->cols / col->rows adjacency with weights, as mutable dicts."""
    Mc = sp.coo_matrix(M)
    row_cols: list[dict[int, float]] = [dict() for _ in range(M.shape[0])]
    col_rows: list[set[int]] = [set() for _ in range(M.shape[1])]
    for r, c, v in zip(Mc.row, Mc.col, Mc.data):
        if v == 0.0:
            continue
        row_cols[r][int(c)] = float(v)
        col_rows[int(c)].add(int(r))
    return row_cols, col_rows


def peel_schedule(
    M: sp.spmatrix | np.ndarray,
    rng: np.random.Generator | None = None,
    root_pick: str = "random",
    check_rank: bool = True,
):
    """Run Algorithm 1 structurally over M; return (schedule, stats).

    root_pick:
      "random"    -- paper's choice: uniformly random unrecovered block.
      "max_rows"  -- beyond-paper heuristic: pick the unrecovered block that
                     appears in the most active rows, maximizing the expected
                     number of new ripples per rooting step (see DESIGN.md
                     section 2 for the measured effect).
      "fail"      -- raise DecodingError instead of rooting (pure peeling,
                     i.e. LT-code decoding semantics).
    """
    M = sp.csr_matrix(M)
    K, d = M.shape
    if check_rank:
        rank = int(np.linalg.matrix_rank(M.toarray()))
        if rank < d:
            raise DecodingError(
                f"coefficient matrix rank {rank} < {d}; "
                "collect more results before decoding"
            )
    rng = rng or np.random.default_rng(0)
    row_cols, col_rows = _adjacency(M)
    recovered = np.zeros(d, dtype=bool)
    schedule: list[tuple] = []
    stats = DecodeStats()

    # Ripple set: rows whose residual degree is exactly 1.
    ripples = {r for r in range(K) if len(row_cols[r]) == 1}

    def subtract_block(col: int):
        """AXPY the recovered block out of every active row containing it."""
        for r in sorted(col_rows[col]):
            w = row_cols[r].pop(col)
            schedule.append(("axpy", r, col, w))
            stats.axpys += 1
            if len(row_cols[r]) == 1:
                ripples.add(r)
            elif len(row_cols[r]) == 0:
                ripples.discard(r)
        col_rows[col].clear()

    num_left = d
    while num_left > 0:
        ripple_row = None
        while ripples:
            r = ripples.pop()
            if len(row_cols[r]) == 1:
                ripple_row = r
                break
        if ripple_row is not None:
            (col, w), = row_cols[ripple_row].items()
            row_cols[ripple_row].clear()
            col_rows[col].discard(ripple_row)
            schedule.append(("peel", ripple_row, col, 1.0 / w))
            stats.peels += 1
            recovered[col] = True
            num_left -= 1
            subtract_block(col)
            continue

        # Rooting step (Lemma 1): no ripple exists.  Solve the residual
        # system restricted to unrecovered columns for a combination that
        # isolates block `col`.
        if root_pick == "fail":
            raise DecodingError("peeling stalled and rooting disabled")
        unrec = np.flatnonzero(~recovered)
        if root_pick == "max_rows":
            col = int(unrec[np.argmax([len(col_rows[c]) for c in unrec])])
        else:
            col = int(rng.choice(unrec))
        active_rows = sorted({r for c in unrec for r in col_rows[c]})
        if not active_rows:
            raise DecodingError("no active rows left but blocks unrecovered")
        R = np.zeros((len(active_rows), len(unrec)))
        for a, r in enumerate(active_rows):
            for c, w in row_cols[r].items():
                R[a, unrec.searchsorted(c)] = w
        e = np.zeros(len(unrec))
        e[unrec.searchsorted(col)] = 1.0
        # Solve R^T u = e  (least squares; consistent because M is full rank).
        u, residual, rank, _ = np.linalg.lstsq(R.T, e, rcond=None)
        if not np.allclose(R.T @ u, e, atol=1e-8):
            raise DecodingError("rooting solve failed; matrix not full rank?")
        nz = np.flatnonzero(np.abs(u) > 1e-12)
        rows = np.asarray([active_rows[i] for i in nz], dtype=np.int64)
        coeffs = u[nz]
        schedule.append(("root", col, rows, coeffs))
        stats.roots += 1
        stats.root_row_combines += len(rows)
        recovered[col] = True
        num_left -= 1
        subtract_block(col)

    return schedule, stats


def apply_schedule(schedule, results):
    """Replay a structural schedule on worker results.

    ``results``: list of blocks (numpy arrays or scipy sparse) indexed by row.
    Returns the list of mn recovered blocks indexed by flat column.
    Rows are consumed destructively on a shallow copy.
    """
    R = list(results)
    d = 1 + max(
        op[2] if op[0] != "root" else op[1] for op in schedule
    ) if schedule else 0
    blocks = [None] * d
    for op in schedule:
        kind = op[0]
        if kind == "peel":
            _, row, col, scale = op
            blocks[col] = R[row] * scale
        elif kind == "root":
            _, col, rows, coeffs = op
            acc = R[rows[0]] * coeffs[0]
            for r, u in zip(rows[1:], coeffs[1:]):
                acc = acc + R[r] * u
            blocks[col] = acc
        elif kind == "axpy":
            _, row, col, w = op
            R[row] = R[row] - blocks[col] * w
        else:  # pragma: no cover
            raise ValueError(f"unknown op {kind}")
    return blocks


def hybrid_decode(M, results, rng=None, root_pick: str = "random"):
    """Algorithm 1 end to end: schedule + replay.  Returns (blocks, stats)."""
    schedule, stats = peel_schedule(M, rng=rng, root_pick=root_pick)
    return apply_schedule(schedule, results), stats


def gaussian_decode(M, results):
    """Reference decoder: solve the full linear system with least squares.

    O(K * mn^2 + mn * rt) -- the dense path the paper's hybrid decoder beats.
    Used as the test oracle and as the decode path for dense baseline codes.
    """
    M = sp.csr_matrix(M).toarray()
    K, d = M.shape
    if np.linalg.matrix_rank(M) < d:
        raise DecodingError("coefficient matrix not full column rank")
    first = next(b for b in results if b is not None)
    # pinv(M) is (d x K) and tiny; applying it block-by-block avoids lstsq's
    # many-RHS pathology and preserves sparsity when the blocks are sparse.
    D = np.linalg.pinv(M)
    D[np.abs(D) < 1e-12] = 0.0
    out = []
    for c in range(d):
        acc = None
        for k in range(K):
            if D[c, k] != 0.0:
                term = results[k] * D[c, k]
                acc = term if acc is None else acc + term
        out.append(acc if acc is not None else first * 0.0)
    return out


def decode_matrix(M: sp.spmatrix | np.ndarray) -> np.ndarray:
    """D = M^+ in R^{mn x K}: decoding as a single linear combine.

    This is the TPU-idiomatic decode: ``blocks = einsum('ck,k...->c...', D,
    results)`` runs on the MXU in one fused contraction.  Mathematically
    identical to Algorithm 1's output (both invert the same full-rank system).
    """
    M = sp.csr_matrix(M).toarray()
    return np.linalg.pinv(M)
