"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the kernels must reproduce; every kernel
test sweeps shapes/dtypes and asserts allclose against these.
"""

from __future__ import annotations

import jax.numpy as jnp


def coded_accum_ref(A, B, cols, weights, m: int, n: int):
    """C~ = sum_l weights[l] * A_{i_l}^T B_{j_l}  with (i, j) = divmod(cols[l], n).

    A: (s, r), B: (s, t); returns (r/m, t/n) in f32.
    Padded slots carry weight 0 and contribute nothing.
    """
    s, r = A.shape
    _, t = B.shape
    br, bt = r // m, t // n
    acc = jnp.zeros((br, bt), jnp.float32)
    for l in range(cols.shape[0]):
        i = cols[l] // n
        j = cols[l] % n
        Ai = jnp.asarray(A)[:, i * br:(i + 1) * br] if isinstance(i, int) else \
            jnp.take(jnp.asarray(A).reshape(s, m, br), i, axis=1)
        Bj = jnp.asarray(B)[:, j * bt:(j + 1) * bt] if isinstance(j, int) else \
            jnp.take(jnp.asarray(B).reshape(s, n, bt), j, axis=1)
        acc = acc + weights[l].astype(jnp.float32) * jnp.einsum(
            "sr,st->rt", Ai.astype(jnp.float32), Bj.astype(jnp.float32))
    return acc


def spmm_block_ref(vals, idx, B, out_rows: int):
    """C = A^T B with A given in block-ELL (see repro.sparse.blocksparse).

    vals: (CB, L, bs, bs) tiles of A; idx: (CB, L) source row-block of A.
    B: (s, t) dense.  Returns C: (out_rows, t) = (CB * bs, t) in f32.
    Padded slots hold zero tiles, so they add nothing.
    """
    CB, L, bs, _ = vals.shape
    s, t = B.shape
    Bt = jnp.asarray(B).reshape(s // bs, bs, t)
    C = jnp.zeros((CB, bs, t), jnp.float32)
    for cb in range(CB):
        acc = jnp.zeros((bs, t), jnp.float32)
        for l in range(L):
            tile = vals[cb, l].astype(jnp.float32)          # (bs, bs) of A
            brows = jnp.take(Bt, idx[cb, l], axis=0).astype(jnp.float32)
            acc = acc + tile.T @ brows
        C = C.at[cb].set(acc)
    return C.reshape(CB * bs, t)


def spmm_block_fused_ref(vals, src, wslot, B, bt: int):
    """Fused-gather semantics: C[cb] = sum_l w[cb,l] * vals[cb,l]^T @
    B[src_rb rows, src_jb-th bt-wide column group].

    vals: (CB, L, bs, bs); src: (CB, L, 2) [row-block, column group];
    wslot: (CB, L); B: (s, t), t divisible by bt.  Returns (CB * bs, bt).
    """
    CB, L, bs, _ = vals.shape
    s, t = B.shape
    B4 = jnp.asarray(B).reshape(s // bs, bs, t // bt, bt)
    C = jnp.zeros((CB, bs, bt), jnp.float32)
    for cb in range(CB):
        acc = jnp.zeros((bs, bt), jnp.float32)
        for l in range(L):
            tile = vals[cb, l].astype(jnp.float32)
            brows = B4[src[cb, l, 0], :, src[cb, l, 1], :].astype(jnp.float32)
            acc = acc + wslot[cb, l].astype(jnp.float32) * (tile.T @ brows)
        C = C.at[cb].set(acc)
    return C.reshape(CB * bs, bt)
