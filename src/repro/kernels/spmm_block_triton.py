"""Pallas-Triton lane of the fused block-sparse kernels (GPU target).

Same math as ``repro.kernels.spmm_block`` — C_k = sum_l w_l * tile_l^T @
B[src_l] with the decode combine optionally fused into the epilogue — but
restructured for the GPU grid model.  Triton grid axes are PARALLEL: there
is no sequential innermost axis to accumulate across, so the slot loop
moves INTO the kernel as a ``lax.fori_loop`` and the tile gather is an
explicit ``pl.load`` with dynamic slices instead of a scalar-prefetched
BlockSpec index_map.  One program instance owns one (row-block, column
tile) output and walks its L packed slots, so the accumulator lives in
registers and the output is written exactly once — decode-fused, each of
the mn decode-weighted copies is written in the same epilogue with no HBM
round-trip of C~.

Compiled-lane caveat: Triton's ``tl.dot`` requires all matmul dimensions
>= 16, so the compiled GPU lane needs block_size >= 16 (the repo default
bs=8 still works under ``interpret=True``, which is what CPU parity tests
and the CI gpu-lane job use).  Interpret mode executes the identical
kernel body, loop structure and all.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _acc_slot(src_ref, w_ref, vals_ref, b_ref, cb, tt, l, acc, *,
              bs: int, t_tile: int, tpg: int):
    """acc += w[cb,l] * vals[cb,l]^T @ B[src row-block, src column tile]."""
    rb = src_ref[cb, l, 0]
    jb = src_ref[cb, l, 1]
    w = w_ref[cb, l].astype(jnp.float32)
    tile = pl.load(
        vals_ref, (cb, l, pl.dslice(0, bs), pl.dslice(0, bs))
    ).astype(jnp.float32)
    b = pl.load(
        b_ref, (rb, pl.dslice(0, bs), pl.dslice((jb * tpg + tt) * t_tile,
                                                t_tile))
    ).astype(jnp.float32)
    return acc + w * jax.lax.dot_general(
        tile, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _fused_kernel_triton(src_ref, w_ref, vals_ref, b_ref, o_ref, *,
                         bs: int, t_tile: int, num_slots: int, tpg: int):
    cb = pl.program_id(0)
    tt = pl.program_id(1)
    acc = jax.lax.fori_loop(
        0, num_slots,
        lambda l, a: _acc_slot(src_ref, w_ref, vals_ref, b_ref, cb, tt, l, a,
                               bs=bs, t_tile=t_tile, tpg=tpg),
        jnp.zeros((bs, t_tile), jnp.float32),
    )
    pl.store(o_ref, (pl.dslice(cb * bs, bs), pl.dslice(tt * t_tile, t_tile)),
             acc)


def _fused_decode_kernel_triton(src_ref, w_ref, d_ref, vals_ref, b_ref, o_ref,
                                *, bs: int, t_tile: int, num_slots: int,
                                tpg: int, mn: int):
    cb = pl.program_id(0)
    tt = pl.program_id(1)
    acc = jax.lax.fori_loop(
        0, num_slots,
        lambda l, a: _acc_slot(src_ref, w_ref, vals_ref, b_ref, cb, tt, l, a,
                               bs=bs, t_tile=t_tile, tpg=tpg),
        jnp.zeros((bs, t_tile), jnp.float32),
    )
    # fused decode epilogue: mn is static, so this unrolls into mn scalar
    # broadcasts + stores of the register-resident accumulator
    for c in range(mn):
        pl.store(
            o_ref,
            (pl.dslice(c, 1), pl.dslice(cb * bs, bs),
             pl.dslice(tt * t_tile, t_tile)),
            (d_ref[c].astype(jnp.float32) * acc)[None],
        )


def _check_shapes(vals, B, bt, t_tile):
    CB, L, bs, _ = vals.shape
    s, t = B.shape
    if bt % t_tile:
        raise ValueError(f"bt={bt} not divisible by t_tile={t_tile}")
    if t % bt:
        raise ValueError(f"t={t} not divisible by column-group width bt={bt}")
    if s % bs:
        raise ValueError(f"s={s} not divisible by block size {bs}")
    return CB, L, bs, s, t


@functools.partial(jax.jit, static_argnames=("bt", "t_tile", "interpret"))
def spmm_block_fused_triton(vals, src, wslot, B, *, bt: int,
                            t_tile: int = 128, interpret: bool = False):
    """Triton lane of ``spmm_block_fused``: (CB*bs, bt) f32."""
    CB, L, bs, s, t = _check_shapes(vals, B, bt, t_tile)
    kernel = functools.partial(
        _fused_kernel_triton, bs=bs, t_tile=t_tile, num_slots=L,
        tpg=bt // t_tile)
    return pl.pallas_call(
        kernel,
        grid=(CB, bt // t_tile),
        out_shape=jax.ShapeDtypeStruct((CB * bs, bt), jnp.float32),
        interpret=interpret,
    )(src.astype(jnp.int32), wslot.astype(jnp.float32), vals,
      B.reshape(s // bs, bs, t))


@functools.partial(jax.jit, static_argnames=("bt", "t_tile", "interpret"))
def spmm_block_fused_decode_triton(vals, src, wslot, dvec, B, *, bt: int,
                                   t_tile: int = 128,
                                   interpret: bool = False):
    """Triton lane of ``spmm_block_fused_decode``: (mn, CB*bs, bt) f32."""
    CB, L, bs, s, t = _check_shapes(vals, B, bt, t_tile)
    (mn,) = dvec.shape
    kernel = functools.partial(
        _fused_decode_kernel_triton, bs=bs, t_tile=t_tile, num_slots=L,
        tpg=bt // t_tile, mn=mn)
    return pl.pallas_call(
        kernel,
        grid=(CB, bt // t_tile),
        out_shape=jax.ShapeDtypeStruct((mn, CB * bs, bt), jnp.float32),
        interpret=interpret,
    )(src.astype(jnp.int32), wslot.astype(jnp.float32),
      dvec.astype(jnp.float32), vals, B.reshape(s // bs, bs, t))
