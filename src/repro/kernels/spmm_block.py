"""Block-sparse SpMM Pallas kernel: C = A^T B with A in block-ELL (TPU target).

TPU adaptation of the paper's sparse local products (DESIGN.md section 3;
the coded-matmul "block_sparse" backend in repro.core.coded_matmul is the
SPMD consumer of this kernel):
unstructured CSR gathers do not map to the MXU, so A is stored as packed
bs x bs tiles (repro.sparse.BlockELL).  Each output row-block rb consumes its
stripe vals[rb, :] of packed tiles; the tile's *source row-block in B* is
scalar-prefetched from idx[rb, l], so the B tile DMA is issued ahead of the
matmul.  Compute and HBM traffic scale with the number of LIVE tiles
(nnz-proportional -- the paper's whole point), not with the dense dimensions.

Grid: (CB, t_tiles, L) -- L innermost so each (rb, tt) output tile stays
VMEM-resident across its accumulation; zero-padded slots multiply zero tiles
and add nothing.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, vals_ref, b_ref, o_ref):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = vals_ref[0, 0].astype(jnp.float32)   # (bs, bs) tile of A
    b = b_ref[0].astype(jnp.float32)            # (bs, t_tile) rows of B
    # C[rb] += tile^T @ B[idx]
    o_ref[...] += jax.lax.dot_general(
        tile, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def resolve_interpret(interpret: bool | None = None) -> bool:
    """The single interpret-mode policy for every Pallas kernel here.

    Explicit argument wins, then the REPRO_PALLAS_INTERPRET env override,
    then backend auto-selection: compiled only on TPU.  The kernels target
    the TPU MXU; everywhere else (CPU containers, tests) the Pallas
    interpreter executes the same body faithfully, BlockSpec tiling
    included.
    """
    if interpret is not None:
        return interpret
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("t_tile", "interpret"))
def spmm_block(vals, idx, B, *, t_tile: int = 128,
               interpret: bool | None = None):
    """C = A^T B, A in block-ELL.

    vals: (CB, L, bs, bs), idx: (CB, L) int32, B: (s, t).
    Returns (CB * bs, t) f32.  t must divide by t_tile, s by bs.
    interpret=None defers to ``resolve_interpret`` (env, then backend).
    """
    if interpret is None:
        interpret = resolve_interpret()
    CB, L, bs, _ = vals.shape
    s, t = B.shape
    if t % t_tile:
        raise ValueError(f"t={t} not divisible by t_tile={t_tile}")
    if s % bs:
        raise ValueError(f"s={s} not divisible by block size {bs}")

    grid = (CB, t // t_tile, L)

    vals_spec = pl.BlockSpec(
        (1, 1, bs, bs), lambda cb, tt, l, idx_ref: (cb, l, 0, 0)
    )
    # B viewed as (s/bs, bs, t): pick row-block idx[cb, l], column tile tt.
    b_spec = pl.BlockSpec(
        (1, bs, t_tile), lambda cb, tt, l, idx_ref: (idx_ref[cb, l], 0, tt)
    )
    o_spec = pl.BlockSpec((bs, t_tile), lambda cb, tt, l, idx_ref: (cb, tt))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[vals_spec, b_spec],
        out_specs=o_spec,
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((CB * bs, t), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), vals, B.reshape(s // bs, bs, t))
