"""Block-sparse SpMM Pallas kernels: C = A^T B with A in block-ELL (TPU target).

TPU adaptation of the paper's sparse local products (DESIGN.md section 3;
the coded-matmul "block_sparse" backend in repro.core.coded_matmul is the
SPMD consumer of these kernels):
unstructured CSR gathers do not map to the MXU, so A is stored as packed
bs x bs tiles (repro.sparse.BlockELL).  Each output row-block rb consumes its
stripe vals[rb, :] of packed tiles; the tile's *source row-block in B* is
scalar-prefetched from idx[rb, l], so the B tile DMA is issued ahead of the
matmul.  Compute and HBM traffic scale with the number of LIVE tiles
(nnz-proportional -- the paper's whole point), not with the dense dimensions.

Two entry points:

* ``spmm_block``   -- the plain kernel: idx addresses row-blocks of the B
  operand as given.  The coded-matmul consumer formerly pre-stacked
  B_k = vstack_l(w_kl B_{j_l}) on device to use it, which materialized an
  O(max_degree * s) dense intermediate per worker.
* ``spmm_block_fused`` -- the fused-gather kernel: the scalar prefetch
  carries, per (cb, l) slot, the source *row-block* AND source *column
  group* of the original (s, t) B plus a per-slot f32 weight; the BlockSpec
  index_map DMAs tiles straight out of B and the kernel scales by the
  prefetched weight.  No stacked copy of B ever exists -- HBM traffic is
  live tiles only.  Off TPU (no env override, no explicit ``interpret``)
  it dispatches to an XLA gather/einsum path with identical semantics:
  the Pallas interpreter is a correctness tool, orders of magnitude
  slower than compiled XLA, and would bury the nnz-proportional win.
* ``spmm_block_fused_decode`` -- the ONE-LAUNCH variant: the survivor
  decode column d = D[:, k] * alive_k enters as a third scalar-prefetched
  operand and the decode combine ``contrib[c] = d[c] * C~_k`` happens in
  the kernel's epilogue -- the local product accumulates into a VMEM
  scratch tile (double-buffered tile DMA exactly as in the fused kernel)
  and on the last slot each of the mn decode-weighted copies is written
  straight to the output block.  The separate ``D @ C~`` contraction (a
  second launch plus an HBM round-trip of C~) disappears from the staged
  program; ``repro.analysis.jaxpr_check.decode_contraction_offenders``
  enforces its absence on the trace.

Grid: (CB, t_tiles, L) -- L innermost so each (rb, tt) output tile stays
VMEM-resident across its accumulation; zero-padded slots multiply zero tiles
(fused: weight 0.0) and add nothing.

Platform lanes: the decode-fused kernel exists on every backend.  TPU runs
this module's compiled Pallas kernel; GPU runs the Pallas-Triton variant
(``repro.kernels.spmm_block_triton``, in-kernel gather loop instead of
index-map prefetch); CPU runs the XLA gather path (or either kernel under
the interpreter for parity tests).  ``resolve_lane`` is the single policy:
REPRO_KERNEL_LANE=tpu|triton|xla overrides, then the default backend picks.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, vals_ref, b_ref, o_ref):
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = vals_ref[0, 0].astype(jnp.float32)   # (bs, bs) tile of A
    b = b_ref[0].astype(jnp.float32)            # (bs, t_tile) rows of B
    # C[rb] += tile^T @ B[idx]
    o_ref[...] += jax.lax.dot_general(
        tile, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def resolve_interpret(interpret: bool | None = None) -> bool:
    """The single interpret-mode policy for every Pallas kernel here.

    Explicit argument wins, then the REPRO_PALLAS_INTERPRET env override,
    then backend auto-selection: compiled only on TPU.  The kernels target
    the TPU MXU; everywhere else (CPU containers, tests) the Pallas
    interpreter executes the same body faithfully, BlockSpec tiling
    included.
    """
    if interpret is not None:
        return interpret
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("t_tile", "interpret"))
def spmm_block(vals, idx, B, *, t_tile: int = 128,
               interpret: bool | None = None):
    """C = A^T B, A in block-ELL.

    vals: (CB, L, bs, bs), idx: (CB, L) int32, B: (s, t).
    Returns (CB * bs, t) f32.  t must divide by t_tile, s by bs.
    interpret=None defers to ``resolve_interpret`` (env, then backend).
    """
    if interpret is None:
        interpret = resolve_interpret()
    CB, L, bs, _ = vals.shape
    s, t = B.shape
    if t % t_tile:
        raise ValueError(f"t={t} not divisible by t_tile={t_tile}")
    if s % bs:
        raise ValueError(f"s={s} not divisible by block size {bs}")

    grid = (CB, t // t_tile, L)

    vals_spec = pl.BlockSpec(
        (1, 1, bs, bs), lambda cb, tt, l, idx_ref: (cb, l, 0, 0)
    )
    # B viewed as (s/bs, bs, t): pick row-block idx[cb, l], column tile tt.
    b_spec = pl.BlockSpec(
        (1, bs, t_tile), lambda cb, tt, l, idx_ref: (idx_ref[cb, l], 0, tt)
    )
    o_spec = pl.BlockSpec((bs, t_tile), lambda cb, tt, l, idx_ref: (cb, tt))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[vals_spec, b_spec],
        out_specs=o_spec,
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((CB * bs, t), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), vals, B.reshape(s // bs, bs, t))


# ------------------------------ fused gather --------------------------------

def _fused_kernel(src_ref, w_ref, vals_ref, b_ref, o_ref):
    cb = pl.program_id(0)
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[cb, l].astype(jnp.float32)        # per-slot code weight
    tile = vals_ref[0, 0].astype(jnp.float32)   # (bs, bs) tile of A
    b = b_ref[0].astype(jnp.float32)            # (bs, t_tile) rows of B
    # C[cb] += w * tile^T @ B[src_rb, :, src_jb-th column group]
    o_ref[...] += w * jax.lax.dot_general(
        tile, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bt", "t_tile"))
def _spmm_block_fused_jnp(vals, src, wslot, B, *, bt: int, t_tile: int = 0):
    """XLA gather/einsum path with the fused kernel's exact semantics.

    The only intermediates are (CB, L, bs, bt) -- proportional to packed
    tile slots, never to max_degree * s.  Used off-TPU where compiled
    Pallas is unavailable and the interpreter is too slow to be a backend.
    """
    del t_tile  # tiling is the compiler's business here
    CB, L, bs, _ = vals.shape
    s, t = B.shape
    B4 = B.reshape(s // bs, bs, t // bt, bt)
    bsel = B4[src[..., 0], :, src[..., 1], :]                # (CB, L, bs, bt)
    scaled = vals.astype(jnp.float32) * wslot[..., None, None].astype(jnp.float32)
    out = jnp.einsum("clio,clit->cot", scaled, bsel.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(CB * bs, bt)


@functools.partial(jax.jit, static_argnames=("bt", "t_tile", "interpret"))
def _spmm_block_fused_pallas(vals, src, wslot, B, *, bt: int,
                             t_tile: int = 128, interpret: bool = False):
    CB, L, bs, _ = vals.shape
    s, t = B.shape
    if bt % t_tile:
        raise ValueError(f"bt={bt} not divisible by t_tile={t_tile}")
    if t % bt:
        raise ValueError(f"t={t} not divisible by column-group width bt={bt}")
    if s % bs:
        raise ValueError(f"s={s} not divisible by block size {bs}")

    grid = (CB, bt // t_tile, L)
    tpg = bt // t_tile  # t_tiles per column group

    vals_spec = pl.BlockSpec(
        (1, 1, bs, bs), lambda cb, tt, l, src_ref, w_ref: (cb, l, 0, 0)
    )
    # B viewed as (s/bs, bs, t): row-block src[cb,l,0], column tile tt of
    # column group src[cb,l,1] -- the gather happens in the DMA, no stacked
    # B copy is ever built.
    b_spec = pl.BlockSpec(
        (1, bs, t_tile),
        lambda cb, tt, l, src_ref, w_ref: (
            src_ref[cb, l, 0], 0, src_ref[cb, l, 1] * tpg + tt),
    )
    o_spec = pl.BlockSpec((bs, t_tile), lambda cb, tt, l, src_ref, w_ref: (cb, tt))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[vals_spec, b_spec],
        out_specs=o_spec,
    )
    return pl.pallas_call(
        _fused_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((CB * bs, bt), jnp.float32),
        interpret=interpret,
    )(src.astype(jnp.int32), wslot.astype(jnp.float32), vals,
      B.reshape(s // bs, bs, t))


def spmm_block_fused(vals, src, wslot, B, *, bt: int, t_tile: int = 128,
                     interpret: bool | None = None):
    """C_k = sum of w * tile^T @ B[row-block, column-group] over packed slots.

    The fused-gather local product: A's packed tiles address the ORIGINAL
    (s, t) operand B directly, so no (max_degree * s, bt) stacked copy is
    materialized.

    vals : (CB, L, bs, bs)  this worker's packed tiles of sparse A
    src  : (CB, L, 2) int32 [source row-block of B (in s/bs), source column
           group (in t/bt)]
    wslot: (CB, L) f32      per-slot code weight (0.0 on padded slots)
    B    : (s, t) with t divisible by bt, the column-group width.

    Returns (CB * bs, bt) f32.  Dispatch: compiled Pallas on TPU; explicit
    ``interpret`` or the REPRO_PALLAS_INTERPRET env force the Pallas path
    (interpreted or compiled); otherwise off-TPU runs the XLA gather path
    (same semantics, same nnz-proportional intermediates).
    """
    if (interpret is None and os.environ.get("REPRO_PALLAS_INTERPRET") is None
            and jax.default_backend() != "tpu"):
        return _spmm_block_fused_jnp(vals, src, wslot, B, bt=bt)
    return _spmm_block_fused_pallas(vals, src, wslot, B, bt=bt, t_tile=t_tile,
                                    interpret=resolve_interpret(interpret))


# ------------------------- fused gather + decode ----------------------------

#: the three implementations of the decode-fused local product, keyed by the
#: name ``resolve_lane`` returns (the table itself lives in kernels.ops to
#: avoid a circular import with the triton module)
KERNEL_LANES = ("tpu", "triton", "xla")


def resolve_lane(lane: str | None = None) -> str:
    """The single platform-dispatch policy for the decode-fused kernel.

    Explicit argument wins, then the REPRO_KERNEL_LANE env override, then
    the REPRO_PALLAS_INTERPRET escape hatch (which historically forced the
    Pallas path and keeps doing so: it forces the TPU-kernel lane, run
    under the interpreter off-TPU), then the default backend: compiled
    Pallas-TPU on TPU, Pallas-Triton on GPU, the XLA gather path on CPU.
    """
    if lane is not None:
        if lane not in KERNEL_LANES:
            raise ValueError(f"kernel lane {lane!r} not in {KERNEL_LANES}")
        return lane
    env = os.environ.get("REPRO_KERNEL_LANE")
    if env:
        if env not in KERNEL_LANES:
            raise ValueError(
                f"REPRO_KERNEL_LANE={env!r} not in {KERNEL_LANES}")
        return env
    pallas_env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if pallas_env is not None and pallas_env != "0":
        return "tpu"
    backend = jax.default_backend()
    if backend == "tpu":
        return "tpu"
    if backend == "gpu":
        return "triton"
    return "xla"


def _fused_decode_kernel(src_ref, w_ref, d_ref, vals_ref, b_ref, o_ref,
                         acc_ref):
    cb = pl.program_id(0)
    l = pl.program_id(2)
    nl = pl.num_programs(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[cb, l].astype(jnp.float32)        # per-slot code weight
    tile = vals_ref[0, 0].astype(jnp.float32)   # (bs, bs) tile of A
    b = b_ref[0].astype(jnp.float32)            # (bs, t_tile) rows of B
    # C~[cb] += w * tile^T @ B[src_rb, :, src_jb-th column group] -- the
    # SAME accumulation (order and all) as the two-step kernel, into VMEM
    # scratch instead of the output ref
    acc_ref[...] += w * jax.lax.dot_general(
        tile, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(l == nl - 1)
    def _epilogue():
        # decode combine, fused: contrib[c] = d[c] * C~[cb] written per
        # output block -- no separate D @ C~ launch, no HBM round-trip of
        # C~.  mn is static (the output block's leading dim), so this is a
        # compile-time loop of scalar-from-SMEM broadcasts.
        acc = acc_ref[...]
        for c in range(o_ref.shape[0]):
            o_ref[c] = d_ref[c].astype(jnp.float32) * acc


@functools.partial(jax.jit, static_argnames=("bt", "t_tile", "interpret"))
def _spmm_block_fused_decode_pallas(vals, src, wslot, dvec, B, *, bt: int,
                                    t_tile: int = 128,
                                    interpret: bool = False):
    CB, L, bs, _ = vals.shape
    s, t = B.shape
    (mn,) = dvec.shape
    if bt % t_tile:
        raise ValueError(f"bt={bt} not divisible by t_tile={t_tile}")
    if t % bt:
        raise ValueError(f"t={t} not divisible by column-group width bt={bt}")
    if s % bs:
        raise ValueError(f"s={s} not divisible by block size {bs}")

    grid = (CB, bt // t_tile, L)
    tpg = bt // t_tile  # t_tiles per column group

    vals_spec = pl.BlockSpec(
        (1, 1, bs, bs), lambda cb, tt, l, src_ref, w_ref, d_ref: (cb, l, 0, 0)
    )
    b_spec = pl.BlockSpec(
        (1, bs, t_tile),
        lambda cb, tt, l, src_ref, w_ref, d_ref: (
            src_ref[cb, l, 0], 0, src_ref[cb, l, 1] * tpg + tt),
    )
    o_spec = pl.BlockSpec(
        (mn, bs, t_tile), lambda cb, tt, l, src_ref, w_ref, d_ref: (0, cb, tt)
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[vals_spec, b_spec],
        out_specs=o_spec,
        scratch_shapes=[pltpu.VMEM((bs, t_tile), jnp.float32)],
    )
    return pl.pallas_call(
        _fused_decode_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mn, CB * bs, bt), jnp.float32),
        interpret=interpret,
    )(src.astype(jnp.int32), wslot.astype(jnp.float32),
      dvec.astype(jnp.float32), vals, B.reshape(s // bs, bs, t))


@functools.partial(jax.jit, static_argnames=("bt",))
def _spmm_block_fused_decode_jnp(vals, src, wslot, dvec, B, *, bt: int):
    """XLA lane of the decode-fused local product.

    The local product is the fused-gather einsum, the decode combine the
    broadcast multiply XLA fuses into it -- bit-identical to staging the
    two steps separately (same ops in the same order), kept as the CPU
    lane where compiled Pallas is unavailable.
    """
    out = _spmm_block_fused_jnp(vals, src, wslot, B, bt=bt)   # (CB*bs, bt)
    return dvec.astype(jnp.float32)[:, None, None] * out[None]
