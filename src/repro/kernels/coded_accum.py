"""Fused coded-accumulation Pallas kernel (TPU target).

The per-worker hot loop of the sparse code:  C~ = sum_l w_l A_{i_l}^T B_{j_l}.
A naive implementation materializes each block product in HBM and adds them
(degree extra HBM round-trips of r/m x t/n f32).  This kernel fuses the whole
combination: for each task slot l and contraction chunk, the relevant A / B
tiles are streamed HBM->VMEM (tile choice driven by the *scalar-prefetched*
task table, so the DMA engine knows the addresses ahead of the MXU), the
128-aligned partial product is accumulated in a VMEM-resident output tile,
and only the final C~ is written back.  HBM traffic drops from
(degree+1) * |C~| writes+reads to exactly |C~| writes.

Grid: (s_chunks, L).  L is innermost so the output tile stays resident while
all task slots accumulate into it (revisit-friendly order for the TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(cols_ref, w_ref, a_ref, b_ref, o_ref):
    sc = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when((sc == 0) & (l == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[l].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)   # (S_CHUNK, br) -- block i_l of A
    b = b_ref[...].astype(jnp.float32)   # (S_CHUNK, bt) -- block j_l of B
    o_ref[...] += w * jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("m", "n", "s_chunk", "interpret"))
def coded_accum(A, B, cols, weights, *, m: int, n: int,
                s_chunk: int = 128, interpret: bool = True):
    """C~ = sum_l weights[l] * A_{i_l}^T B_{j_l}, fused.

    A: (s, r), B: (s, t); cols/weights: (L,) task table (padded with w=0).
    Returns (r/m, t/n) f32.  s must divide by s_chunk, r by m, t by n.
    interpret=True validates on CPU; on a real TPU pass interpret=False.
    """
    s, r = A.shape
    _, t = B.shape
    br, bt = r // m, t // n
    L = cols.shape[0]
    if s % s_chunk:
        raise ValueError(f"s={s} not divisible by s_chunk={s_chunk}")

    grid = (s // s_chunk, L)

    a_spec = pl.BlockSpec(
        (s_chunk, br), lambda sc, l, cols_ref, w_ref: (sc, cols_ref[l] // n)
    )
    b_spec = pl.BlockSpec(
        (s_chunk, bt), lambda sc, l, cols_ref, w_ref: (sc, cols_ref[l] % n)
    )
    o_spec = pl.BlockSpec((br, bt), lambda sc, l, cols_ref, w_ref: (0, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=o_spec,
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((br, bt), jnp.float32),
        interpret=interpret,
    )(cols.astype(jnp.int32), weights, A, B)
