"""Public jit'd entry points for the Pallas kernels.

On this CPU container the kernels execute via interpret=True (the Pallas
interpreter runs the kernel body faithfully, including BlockSpec tiling);
on a real TPU set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False).
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.coded_accum import coded_accum as _coded_accum
from repro.kernels.spmm_block import spmm_block as _spmm_block
from repro.kernels import ref as ref  # re-export oracle for callers/tests


def _default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def coded_accum(A, B, cols, weights, *, m: int, n: int, s_chunk: int = 128,
                interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _coded_accum(A, B, cols, weights, m=m, n=n, s_chunk=s_chunk,
                        interpret=interp)


def spmm_block(vals, idx, B, *, t_tile: int = 128, interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _spmm_block(vals, idx, B, t_tile=t_tile, interpret=interp)
