"""Public jit'd entry points for the Pallas kernels.

Interpret mode is auto-selected from the backend: compiled kernels on TPU,
the Pallas interpreter everywhere else (it runs the kernel body faithfully,
including BlockSpec tiling).  Override per-call with ``interpret=`` or
globally with REPRO_PALLAS_INTERPRET=0/1 (one shared policy:
``repro.kernels.spmm_block.resolve_interpret``).

The fused kernels additionally dispatch across PLATFORM LANES (one policy:
``repro.kernels.spmm_block.resolve_lane``, REPRO_KERNEL_LANE=tpu|triton|xla
to override): compiled Pallas-TPU on TPU, Pallas-Triton on GPU, and the
XLA gather path on CPU, where the interpreter would bury the
nnz-proportional win.  The dispatch table lives here, not in spmm_block,
so the TPU and Triton kernel modules never import each other.
"""

from __future__ import annotations

import jax

from repro.kernels.coded_accum import coded_accum as _coded_accum
from repro.kernels.spmm_block import (
    resolve_interpret,
    resolve_lane,
    spmm_block as _spmm_block,
    spmm_block_fused as _spmm_block_fused,
    _spmm_block_fused_decode_jnp,
    _spmm_block_fused_decode_pallas,
)
from repro.kernels.spmm_block_triton import (
    spmm_block_fused_decode_triton,
    spmm_block_fused_triton,
)
from repro.kernels import ref as ref  # re-export oracle for callers/tests


def _triton_interpret(interpret: bool | None) -> bool:
    # compiled Triton only where there is a GPU to compile for; interpret
    # everywhere else (CPU parity tests, the CI gpu-lane job)
    if interpret is not None:
        return interpret
    return jax.default_backend() != "gpu"


def coded_accum(A, B, cols, weights, *, m: int, n: int, s_chunk: int = 128,
                interpret: bool | None = None):
    return _coded_accum(A, B, cols, weights, m=m, n=n, s_chunk=s_chunk,
                        interpret=resolve_interpret(interpret))


def spmm_block(vals, idx, B, *, t_tile: int = 128, interpret: bool | None = None):
    return _spmm_block(vals, idx, B, t_tile=t_tile,
                       interpret=resolve_interpret(interpret))


def spmm_block_fused(vals, src, wslot, B, *, bt: int, t_tile: int = 128,
                     interpret: bool | None = None, lane: str | None = None):
    lane = resolve_lane(lane)
    if lane == "triton":
        return spmm_block_fused_triton(
            vals, src, wslot, B, bt=bt, t_tile=t_tile,
            interpret=_triton_interpret(interpret))
    # "tpu" and "xla" lanes: spmm_block_fused keeps its historical internal
    # dispatch (compiled/interpreted Pallas vs the XLA gather path)
    if lane == "xla" and interpret is None:
        interpret = None  # let the internal policy pick the XLA path
    return _spmm_block_fused(vals, src, wslot, B, bt=bt, t_tile=t_tile,
                             interpret=interpret)


def spmm_block_fused_decode(vals, src, wslot, dvec, B, *, bt: int,
                            t_tile: int = 128, interpret: bool | None = None,
                            lane: str | None = None):
    """One-launch coded local product + decode combine: (mn, CB*bs, bt) f32.

    dvec is this worker's survivor decode column ``D[:, k] * alive_k``
    (mn,); the output stacks the mn decode-weighted copies of the local
    product, ready for the psum that replaces the old ``D @ C~``
    contraction.
    """
    lane = resolve_lane(lane)
    if lane == "xla":
        return _spmm_block_fused_decode_jnp(vals, src, wslot, dvec, B, bt=bt)
    if lane == "triton":
        return spmm_block_fused_decode_triton(
            vals, src, wslot, dvec, B, bt=bt, t_tile=t_tile,
            interpret=_triton_interpret(interpret))
    return _spmm_block_fused_decode_pallas(
        vals, src, wslot, dvec, B, bt=bt, t_tile=t_tile,
        interpret=resolve_interpret(interpret))
