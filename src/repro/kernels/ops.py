"""Public jit'd entry points for the Pallas kernels.

Interpret mode is auto-selected from the backend: compiled kernels on TPU,
the Pallas interpreter everywhere else (it runs the kernel body faithfully,
including BlockSpec tiling).  Override per-call with ``interpret=`` or
globally with REPRO_PALLAS_INTERPRET=0/1 (one shared policy:
``repro.kernels.spmm_block.resolve_interpret``).
"""

from __future__ import annotations

from repro.kernels.coded_accum import coded_accum as _coded_accum
from repro.kernels.spmm_block import (
    resolve_interpret,
    spmm_block as _spmm_block,
    spmm_block_fused as _spmm_block_fused,
)
from repro.kernels import ref as ref  # re-export oracle for callers/tests


def coded_accum(A, B, cols, weights, *, m: int, n: int, s_chunk: int = 128,
                interpret: bool | None = None):
    return _coded_accum(A, B, cols, weights, m=m, n=n, s_chunk=s_chunk,
                        interpret=resolve_interpret(interpret))


def spmm_block(vals, idx, B, *, t_tile: int = 128, interpret: bool | None = None):
    return _spmm_block(vals, idx, B, t_tile=t_tile,
                       interpret=resolve_interpret(interpret))


def spmm_block_fused(vals, src, wslot, B, *, bt: int, t_tile: int = 128,
                     interpret: bool | None = None):
    # dispatch (Pallas vs XLA gather path) lives in spmm_block_fused itself:
    # interpret=None means "fastest correct path for this backend"
    return _spmm_block_fused(vals, src, wslot, B, bt=bt, t_tile=t_tile,
                             interpret=interpret)
