"""JAX version-compatibility layer (DESIGN.md section 4).

Policy: every JAX API whose location or signature changed across the
versions we support is accessed ONLY through this module.  Call sites never
touch ``jax.shard_map`` / ``jax.experimental.shard_map`` /
``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)``
directly -- that rule is what lets a single checkout run on the whole
support matrix:

  =============  =====================================  ==================
  JAX            shard_map                              AxisType / mesh
  =============  =====================================  ==================
  0.4.35-0.4.x   jax.experimental.shard_map(check_rep)  no AxisType; plain
                                                        jax.make_mesh
  0.5.x-0.6.x    jax.experimental (top-level appears    AxisType appears;
                 late in the range)                     axis_types kwarg
  >= 0.7         jax.shard_map(check_vma)               jax.sharding.AxisType
  =============  =====================================  ==================

``shard_map`` here accepts BOTH spellings of the replication-check flag
(``check_vma`` is the new name of ``check_rep``) and forwards whichever one
the installed JAX understands.  ``AxisType`` is the real enum when present
and an inert stand-in otherwise (on old JAX every mesh axis behaves as
Auto, so dropping the annotation is semantically a no-op).
"""

from __future__ import annotations

import enum
import inspect

import jax

JAX_VERSION: tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit()
)

# --------------------------------- shard_map --------------------------------

_SHARD_MAP = getattr(jax, "shard_map", None)
if _SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _SHARD_MAP
# decide the flag spelling by signature, not by where the function lives:
# the top-level export appeared before the check_rep -> check_vma rename
_SHARD_MAP_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_SHARD_MAP).parameters
    else "check_rep"
)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, check_rep=None,
              **kwargs):
    """Version-portable ``shard_map``.

    ``check_vma`` (new spelling) and ``check_rep`` (old spelling) are
    aliases for the same replication check; pass at most one.
    """
    if check_vma is not None and check_rep is not None and check_vma != check_rep:
        raise ValueError(
            f"check_vma={check_vma} and check_rep={check_rep} disagree; "
            "they are two spellings of the same flag")
    check = check_vma if check_vma is not None else check_rep
    if check is None:
        check = True
    kwargs[_SHARD_MAP_CHECK_KW] = check
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def psum_scatter(x, axis_name, *, scatter_dimension: int = 0, tiled: bool = True):
    """Version-portable ``lax.psum_scatter`` (reduce-scatter over a mesh axis).

    The decode-sharding path goes through here per the module policy: the
    collective has lived at ``jax.lax.psum_scatter`` since 0.2.x, but routing
    it through compat keeps call sites insulated if the signature moves the
    way shard_map's did.  ``tiled=True`` splits ``scatter_dimension`` (which
    must divide by the axis size) instead of adding a leading axis.
    """
    return jax.lax.psum_scatter(
        x, axis_name, scatter_dimension=scatter_dimension, tiled=tiled)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized across JAX versions.

    Old JAX (<= 0.4.x) returns a one-element list of per-program dicts; new
    JAX returns the dict itself.  Always returns a dict (empty if absent).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


# ----------------------------- AxisType / meshes ----------------------------

_REAL_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)

if _REAL_AXIS_TYPE is not None:  # pragma: no cover - new JAX only
    AxisType = _REAL_AXIS_TYPE
else:
    class AxisType(enum.Enum):
        """Stand-in for ``jax.sharding.AxisType`` on JAX < 0.5.

        Old JAX has no explicit-sharding axis types: every mesh axis is
        implicitly Auto, so carrying the annotation (and dropping it at the
        ``make_mesh`` boundary) preserves semantics.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def auto_axis_types(n: int) -> tuple:
    """``(AxisType.Auto,) * n`` -- the annotation every current mesh uses."""
    return (AxisType.Auto,) * n


_MAKE_MESH = getattr(jax, "make_mesh", None)
_MAKE_MESH_HAS_AXIS_TYPES = (
    _MAKE_MESH is not None
    and "axis_types" in inspect.signature(_MAKE_MESH).parameters
)


def make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
    """``jax.make_mesh`` that tolerates the ``axis_types`` kwarg everywhere.

    On JAX without axis types the annotation is dropped (see ``AxisType``);
    on JAX without ``jax.make_mesh`` at all, the mesh is assembled from
    ``mesh_utils.create_device_mesh``.
    """
    if _MAKE_MESH is not None:
        kwargs = {}
        if devices is not None:
            kwargs["devices"] = devices
        if axis_types is not None and _MAKE_MESH_HAS_AXIS_TYPES:
            kwargs["axis_types"] = axis_types  # pragma: no cover - new JAX
        return _MAKE_MESH(tuple(axis_shapes), tuple(axis_names), **kwargs)
    from jax.experimental import mesh_utils  # pragma: no cover - old JAX

    dev_mesh = mesh_utils.create_device_mesh(  # pragma: no cover
        tuple(axis_shapes), devices=devices)
    return jax.sharding.Mesh(dev_mesh, tuple(axis_names))  # pragma: no cover
