"""Frozen configuration for one coded-matmul deployment.

``CodedMatmulConfig`` replaces the flat-kwarg sprawl the legacy
``coded_matmul(...)`` signature accreted (12 parameters, several valid for
only one backend): every execution knob is validated ONCE at construction
against the live registries (``repro.coded.registry`` for schemes,
``repro.core.coded_backends`` for backends), so an op built from a config
can never reach staging with an unknown scheme/backend, and new backends
or schemes become legal values by registration alone -- no hardcoded
tuples to desync.

jax-free on purpose: ``repro.configs.ArchConfig`` embeds one of these and
the config layer must stay importable before XLA_FLAGS are set.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import coded_backends
from repro.coded import registry


@dataclasses.dataclass(frozen=True)
class CodedMatmulConfig:
    """How a coded matmul executes (not WHAT it computes -- that is the plan).

    scheme      -- code design name in the scheme registry
    backend     -- local-compute strategy name in the backend registry;
                   ``"auto"`` defers the block_sparse/dense_scan choice to
                   the measured live-tile density of the packed operand
                   (below ``auto_density_threshold`` -> block_sparse)
    block_size  -- tile edge for auto-packing A on pack-consuming backends
    out_sharded -- decode collective: False = replicated psum, True =
                   psum_scatter (each device reduces only its block shard)
    out_dtype   -- result dtype (any np.dtype spelling; normalized)
    axis_name   -- the mesh axis that plays the worker axis
    compute_dtype -- tile dtype of the packed coded compute: "float32"
                   (exact), "bfloat16", or "int8" (per-tile scales, folded
                   into the coding weights at staging time).  Quantized
                   dtypes are budgeted against the scheme's ``cond_warn``
                   decode-conditioning declaration at construction:
                   eps(dtype) * cond_warn must stay within the global
                   budget, so an ill-conditioned scheme (e.g. ``product``)
                   cannot silently run int8.
    auto_density_threshold -- live-tile fraction above which ``"auto"``
                   picks dense_scan (BENCH data: block_sparse wins clearly
                   at <= 10% density, loses by ~30%)
    """

    scheme: str = "sparse_code"
    backend: str = "dense_scan"
    block_size: int = 8
    out_sharded: bool = False
    out_dtype: str = "float32"
    axis_name: str = "model"
    compute_dtype: str = "float32"
    auto_density_threshold: float = 0.25

    def __post_init__(self):
        registry.get_scheme(self.scheme)           # raises with known names
        coded_backends.get_backend(self.backend)   # raises with known names
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {self.block_size}")
        if not self.axis_name:
            raise ValueError("axis_name must be a non-empty mesh axis name")
        if not 0.0 <= self.auto_density_threshold <= 1.0:
            raise ValueError(
                "auto_density_threshold is a live-tile fraction in [0, 1], "
                f"got {self.auto_density_threshold}")
        if self.compute_dtype not in coded_backends.QUANT_EPS:
            raise ValueError(
                f"compute_dtype {self.compute_dtype!r} not in "
                f"{sorted(coded_backends.QUANT_EPS)}")
        if self.compute_dtype != "float32":
            if not coded_backends.get_backend(self.backend).needs_pack:
                raise ValueError(
                    f"compute_dtype {self.compute_dtype!r} quantizes the "
                    f"PACKED tiles; backend {self.backend!r} takes no pack "
                    "-- use block_sparse (or auto)")
            eps = coded_backends.QUANT_EPS[self.compute_dtype]
            cond = registry.get_scheme(self.scheme).invariants.cond_warn
            if eps * cond > coded_backends.QUANT_COND_BUDGET:
                raise ValueError(
                    f"scheme {self.scheme!r} declares decode conditioning "
                    f"up to {cond:.0e}; {self.compute_dtype} tile rounding "
                    f"(eps={eps:.1e}) could amplify to {eps * cond:.1e} "
                    f"> budget {coded_backends.QUANT_COND_BUDGET:.0e} -- "
                    "use float32 for this scheme")
        # normalize any dtype spelling (np.float32, "f4", jnp dtypes) to the
        # canonical name so configs stay hashable and comparable
        canonical = np.dtype(self.out_dtype).name
        # the dtype policy (repro.analysis jaxpr layer: no silent float64 on
        # device) holds by construction: reject EVERY spelling that
        # normalizes to a 64-bit float/complex, since jax would silently
        # truncate it to f32 anyway under the default x64-disabled config
        if canonical in ("float64", "complex128"):
            raise ValueError(
                f"out_dtype {self.out_dtype!r} normalizes to {canonical}: "
                "the device path is f32-accumulated by design (DESIGN.md "
                "section 9 dtype policy); use float32/bfloat16/float16")
        object.__setattr__(self, "out_dtype", canonical)

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.out_dtype)
