"""The coded-matmul op object: plan -> bind -> apply.

One object owns what the legacy flat-kwarg ``coded_matmul(...)`` spread
over 12 parameters and three layers of callers:

* **plan**   -- ``plan(config, m, n, num_workers)`` designs the code through
  the scheme registry (or ``from_plan(config, p)`` wraps a prebuilt
  ``CodedMatmulPlan``) and returns an unbound ``CodedOp``;
* **bind**   -- ``op.bind(mesh)`` attaches the mesh (validating the worker
  axis against the plan once, not on every call) and yields a callable;
* **apply**  -- ``op(A, B)`` stages and runs the shard_map program.  Backend
  dispatch, BlockELL packing, and the runtime pack cache consultation all
  live here -- callers never thread ``pack=``/``a_sparse=``/``survivors=``
  through intermediate layers;
* **rebind** -- ``op.with_survivors(mask)`` re-derives the decode matrix
  from surviving rows eagerly (raising ``DecodingError`` at rebind time,
  not mid-step) and reuses the existing tile pack, which depends only on
  the task table and never on the decode matrix.

Ops are frozen: every transition returns a new op, so a bound op can be
closed over by jit and shared across threads.  ``op.apply`` is
bit-identical to the legacy ``coded_matmul`` for the same inputs -- both
funnel into ``repro.core.coded_matmul.stage_coded_matmul`` (test-enforced
parity across backends x survivor masks x decode layouts).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.coded.config import CodedMatmulConfig
from repro.coded import registry
from repro.core import coded_backends
from repro.core.coded_matmul import (
    CodedMatmulPlan,
    WorkerTilePack,
    _check_operands,
    chunk_mask_progress,
    resolve_pack,
    stage_coded_matmul,
)
from repro.sparse.blocksparse import BlockELL, dense_to_block_ell


@dataclasses.dataclass(frozen=True)
class CodedOp:
    """A coded matmul, fully described: design + execution config (+ mesh).

    Build with ``plan(...)`` / ``from_plan(...)``, not directly.
    ``plan_`` is the survivor-adjusted plan actually staged; ``base_plan``
    keeps the original design so tile packs (which depend only on the task
    table) are cached and reused across survivor rebinds.
    """

    config: CodedMatmulConfig
    plan_: CodedMatmulPlan
    base_plan: CodedMatmulPlan
    survivors: np.ndarray | None = None
    mesh: object | None = None
    chunk_progress: np.ndarray | None = None  # (N,) chunks completed, if partial

    # ------------------------------ lifecycle -------------------------------

    def bind(self, mesh=None) -> "CodedOp":
        """Attach a mesh (default: a fresh 1-D mesh over every visible
        device, axis named ``config.axis_name``) and validate the worker
        axis size against the plan once."""
        if mesh is None:
            import jax

            from repro import compat

            mesh = compat.make_mesh((len(jax.devices()),),
                                    (self.config.axis_name,))
        axis = self.config.axis_name
        if axis not in mesh.shape:
            raise ValueError(
                f"mesh has no axis {axis!r}: axes are {tuple(mesh.shape)}")
        if mesh.shape[axis] != self.plan_.num_workers:
            raise ValueError(
                f"mesh axis {axis}={mesh.shape[axis]} != plan workers "
                f"{self.plan_.num_workers}")
        return dataclasses.replace(self, mesh=mesh)

    def with_survivors(self, survivors) -> "CodedOp":
        """Rebind to a liveness mask (replaces any previous mask).

        ``survivors`` is an (N,) worker mask, or an (N, q) per-chunk
        completion mask (prefix-form rows: ordered sub-task streams) -- a
        device that completed only its first chunks contributes exactly
        those slots to the decode instead of being zeroed wholesale.  The
        decode matrix is re-derived NOW -- an undecodable mask raises
        ``DecodingError`` here, at rebind time.  Tile packs are reused
        either way: they depend only on the base task table.  Passing None
        (or an all-complete mask) restores the original plan.
        """
        if survivors is None:
            return dataclasses.replace(self, plan_=self.base_plan,
                                       survivors=None, chunk_progress=None)
        mask = np.asarray(survivors, dtype=bool)
        if mask.ndim == 2:
            progress = chunk_mask_progress(mask, self.base_plan.num_workers)
            return dataclasses.replace(
                self,
                plan_=self.base_plan.with_chunk_progress(
                    progress, mask.shape[1]),
                survivors=progress > 0, chunk_progress=progress)
        mask = mask.reshape(-1)
        return dataclasses.replace(
            self, plan_=self.base_plan.with_survivors(mask), survivors=mask,
            chunk_progress=None)

    # ------------------------------- execution ------------------------------

    def pack_for(self, a_sparse: BlockELL, *, use_cache: bool = True) -> WorkerTilePack:
        """The worker tile pack of ``a_sparse`` under this op's design,
        memoized in the runtime pack cache (packs depend only on the task
        table and the config's compute_dtype, so one pack serves every
        survivor rebind of this op)."""
        if use_cache:
            from repro.runtime import pack_cache

            return pack_cache.get_pack(a_sparse, self.base_plan,
                                       compute_dtype=self.config.compute_dtype)
        from repro.core.coded_matmul import pack_worker_tiles

        return pack_worker_tiles(a_sparse, self.base_plan,
                                 compute_dtype=self.config.compute_dtype)

    def _auto_backend(self, A, a_sparse, pack, s: int):
        """Resolve ``backend="auto"``: measure live-tile density, pick.

        Returns ``(backend_name, density, a_sparse)`` -- the BlockELL is
        passed back so a pack built from a concrete A is not rebuilt.
        """
        cfg = self.config
        if a_sparse is not None:
            frac = a_sparse.density()
        elif pack is not None:
            # dense-equivalent tile count of the pack: every live slot of
            # every worker could touch all s/bs row-blocks of its stripe
            degrees = np.count_nonzero(self.base_plan.weights, axis=1)
            cbl = pack.vals.shape[1]
            dense_eq = max(1, int(degrees.sum()) * cbl * (s // pack.block_size))
            frac = float(np.asarray(pack.live_tiles).sum()) / dense_eq
        else:
            import jax

            if isinstance(A, jax.core.Tracer):
                raise ValueError(
                    "backend='auto' under jit needs a_sparse= (a host "
                    "BlockELL) or pack= to measure live-tile density: it "
                    "cannot be derived from a traced operand")
            a_sparse = dense_to_block_ell(np.asarray(A, dtype=np.float32),
                                          block_size=cfg.block_size)
            frac = a_sparse.density()
        chosen = ("block_sparse" if frac <= cfg.auto_density_threshold
                  else "dense_scan")
        return chosen, frac, a_sparse

    def apply(self, A, B, *, a_sparse: BlockELL | None = None,
              pack: WorkerTilePack | None = None):
        """C = A^T B under this op's code, config, and survivor mask.

        For pack-consuming backends (``block_sparse``), pass ``a_sparse``
        (a host BlockELL of A -- packed once and memoized via the runtime
        pack cache) or ``pack`` (a prebuilt ``WorkerTilePack``); a concrete
        (non-traced) A is packed automatically with ``config.block_size``.
        Backends that take no pack reject these operands outright instead
        of silently ignoring them.  ``backend="auto"`` measures the
        operand's live-tile fraction against
        ``config.auto_density_threshold`` and dispatches to block_sparse
        (sparse enough) or dense_scan; the density inputs are consumed by
        that decision and simply dropped when dense_scan wins.
        """
        if self.mesh is None:
            raise ValueError(
                "unbound CodedOp: call .bind(mesh) (or .bind()) first")
        cfg = self.config
        backend = cfg.backend
        entry = coded_backends.get_backend(backend)
        if not entry.needs_pack and (a_sparse is not None or pack is not None):
            raise ValueError(
                f"backend {backend!r} takes no a_sparse/pack operand")
        N, s, r, _, br, _ = _check_operands(A, B, self.plan_, self.mesh,
                                            cfg.axis_name)
        if entry.virtual:
            backend, _, a_sparse = self._auto_backend(A, a_sparse, pack, s)
            entry = coded_backends.get_backend(backend)
            if not entry.needs_pack:
                a_sparse = pack = None
        if entry.needs_pack:
            if pack is None and a_sparse is not None:
                pack = self.pack_for(a_sparse)
            pack = resolve_pack(
                A, self.base_plan, pack=pack, a_sparse=a_sparse,
                block_size=cfg.block_size, compute_dtype=cfg.compute_dtype,
                num_workers=N, s=s, r=r, br=br)
        return stage_coded_matmul(
            A, B, self.plan_, self.mesh,
            axis_name=cfg.axis_name,
            alive=self.survivors,
            out_dtype=cfg.np_dtype,
            backend=backend,
            pack=pack,
            out_sharded=cfg.out_sharded)

    __call__ = apply

    # ------------------------------ introspection ---------------------------

    @property
    def num_workers(self) -> int:
        return self.plan_.num_workers

    @property
    def needs_pack(self) -> bool:
        """Whether this op's backend consumes host-side pack metadata."""
        return coded_backends.get_backend(self.config.backend).needs_pack

    @property
    def bound(self) -> bool:
        return self.mesh is not None

    def __repr__(self) -> str:  # the dataclass default dumps whole ndarrays
        surv = (None if self.survivors is None
                else int(self.survivors.sum()))
        chunks = ("" if self.chunk_progress is None
                  else f", chunk_progress={self.chunk_progress.tolist()}")
        return (f"CodedOp(scheme={self.config.scheme!r}, "
                f"backend={self.config.backend!r}, "
                f"m={self.plan_.m}, n={self.plan_.n}, "
                f"workers={self.num_workers}, "
                f"survivors={surv}{chunks}, bound={self.bound})")


def plan(config: CodedMatmulConfig, m: int, n: int,
         num_workers: int | None = None, *, seed: int = 0,
         max_degree: int | None = None, **scheme_kwargs) -> CodedOp:
    """Design a code for an (m x n)-blocked A^T B over ``num_workers``
    devices and wrap it in an unbound ``CodedOp``.

    The design comes from the scheme registry entry named by
    ``config.scheme``, so the host path (``get_scheme(...).instance``) and
    this device op realize the same generator matrix.
    """
    scheme = registry.get_scheme(config.scheme)
    p = scheme.plan(m, n, num_workers, max_degree=max_degree, seed=seed,
                    **scheme_kwargs)
    return CodedOp(config=config, plan_=p, base_plan=p)


def from_plan(config: CodedMatmulConfig, p: CodedMatmulPlan) -> CodedOp:
    """Wrap a prebuilt ``CodedMatmulPlan`` (e.g. from ``make_plan``) in an
    unbound ``CodedOp`` -- the migration path for callers that already own
    plan objects."""
    return CodedOp(config=config, plan_=p, base_plan=p)
