"""repro.coded: the single public entry point for coded matmul.

Three pieces (DESIGN.md section 7):

* **scheme registry** (``register_scheme`` / ``get_scheme`` /
  ``scheme_names``) -- every code design by name, producing both the host
  ``CodeInstance`` and the device ``CodedMatmulPlan`` from one sampled
  generator matrix;
* **CodedMatmulConfig** -- frozen execution config, validated once at
  construction against the scheme and backend registries;
* **CodedOp** (``plan`` / ``from_plan`` -> ``bind`` -> apply) -- the op
  object that owns backend dispatch, BlockELL packing, the runtime pack
  cache, and survivor rebinding (``with_survivors``).

Quick tour::

    from repro.coded import CodedMatmulConfig, plan

    cfg = CodedMatmulConfig(scheme="sparse_code", backend="block_sparse")
    op = plan(cfg, m=2, n=2, num_workers=8).bind(mesh)
    C = op(A, B, a_sparse=ell)                 # all workers
    C = op.with_survivors(mask)(A, B, a_sparse=ell)  # straggler rebind

Exports resolve lazily (PEP 562): importing the registry/config surface
never pulls in jax, so ``repro.configs`` can validate against this package
before XLA_FLAGS are set.
"""

from repro.coded.config import CodedMatmulConfig
from repro.coded.registry import (
    CodeDesign,
    Scheme,
    get_scheme,
    register_scheme,
    scheme_names,
)

__all__ = [
    "CodedMatmulConfig",
    "CodedOp",
    "CodeDesign",
    "Scheme",
    "from_plan",
    "get_scheme",
    "plan",
    "register_scheme",
    "scheme_names",
]

_LAZY = {"CodedOp", "plan", "from_plan"}  # jax-importing surface (op.py)


def __getattr__(name):
    if name in _LAZY:
        from repro.coded import op as _op

        return getattr(_op, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
