"""Scheme registry: every coded-computation scheme, selectable by name.

A *scheme* is a code design in the block domain (paper section II): a rule
for building the generator matrix M over the mn unknown block products.
The registry gives each design one name and one object able to produce
BOTH execution artifacts from the same sampled M:

* ``Scheme.instance(...)``  -> ``repro.core.schemes.CodeInstance`` -- the
  host master/worker path (event-driven simulation, live threads, peeling
  decode);
* ``Scheme.plan(...)``      -> ``repro.core.coded_matmul.CodedMatmulPlan``
  -- the SPMD device path (one row per device, linear psum decode).

Historically those two were built by unrelated code paths
(``schemes.sparse_code`` vs ``make_plan``) that could silently disagree on
the sampled code; here the device plan is derived from the *instance's own
generator matrix*, so host and device execute the same design by
construction (``plan.coefficient_matrix() == instance.M`` up to degree
truncation -- test-enforced).

This module is jax-free (numpy/scipy only); ``Scheme.plan`` imports the
device-plan types lazily so the registry stays importable before XLA_FLAGS
are set.

Registering a new scheme::

    @register_scheme("my_code")
    def my_code(m, n, N, seed=0):      # -> CodeInstance
        ...

After that, ``get_scheme("my_code")`` serves both paths and the name is a
legal ``CodedMatmulConfig.scheme`` value.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import schemes as schemes_lib
from repro.core.schemes import CodeInstance, SchemeInvariants


@dataclasses.dataclass(frozen=True)
class CodeDesign:
    """Static identity of a registry-built device plan (duck-typed stand-in
    for ``SparseCodeSpec`` in ``CodedMatmulPlan.spec``: exposes the m/n/
    num_workers the plan properties read, plus provenance)."""

    m: int
    n: int
    num_workers: int
    scheme: str
    seed: int

    @property
    def mn(self) -> int:
        return self.m * self.n


@dataclasses.dataclass(frozen=True)
class Scheme:
    """One registered code design; builds host instances and device plans."""

    name: str
    builder: Callable[..., CodeInstance]   # (m, n, N, *, seed=..., **kw)
    fixed_workers: bool = False            # uncoded: N is forced to m*n
    truncates: bool = False                # degree-distribution designs get
    #   the lockstep default truncation (~2 ln(mn)) in plan(); dense designs
    #   keep every entry of their rows
    #: static decodability profile ``repro.analysis`` validates against;
    #: None = the checker's permissive default (custom schemes should
    #: declare one)
    invariants: SchemeInvariants | None = None

    def instance(self, m: int, n: int, num_workers: int | None = None,
                 *, seed: int = 0, **kwargs) -> CodeInstance:
        """The host-path realization (``CodeInstance``) of this design."""
        if self.fixed_workers:
            if num_workers not in (None, m * n):
                raise ValueError(
                    f"scheme {self.name!r} uses exactly m*n={m * n} workers, "
                    f"got num_workers={num_workers}")
            return self.builder(m, n)
        if num_workers is None:
            raise ValueError(f"scheme {self.name!r} needs num_workers")
        return self.builder(m, n, num_workers, seed=seed, **kwargs)

    def chunked(self, m: int, n: int, num_workers: int | None = None, *,
                num_chunks: int, seed: int = 0, **kwargs):
        """Chunk-granular host realization: ``instance(...).chunked(q)``.

        Every registered scheme supports this -- chunking operates on the
        sampled generator matrix, so it passes through the registry with no
        per-scheme code (chunked-vs-atomic decode parity is test-enforced
        across the whole registry).
        """
        return self.instance(m, n, num_workers, seed=seed,
                             **kwargs).chunked(num_chunks)

    def device_capable(self, m: int = 2, n: int = 2,
                       num_workers: int | None = None, **kwargs) -> bool:
        """Whether this design maps onto the SPMD path (one generator row
        per worker = one device)."""
        inst = self.instance(m, n, num_workers or 4 * m * n, **kwargs)
        return all(len(rows) == 1 for rows in inst.worker_rows)

    def plan(self, m: int, n: int, num_workers: int | None = None, *,
             max_degree: int | None = None, seed: int = 0,
             max_resample: int = 50, **kwargs):
        """The device-path plan (``CodedMatmulPlan``) of the same design.

        Derived from the instance's generator matrix: rows are truncated to
        ``max_degree`` task slots (None = the instance's own max row degree,
        i.e. no truncation), the truncated system is rank-checked, and the
        linear decode matrix is its pseudo-inverse.  Resamples ``seed + i``
        until full rank, exactly like ``make_plan``.
        """
        from repro.core.coded_matmul import CodedMatmulPlan
        from repro.core.decoder import decode_matrix

        d = m * n
        if max_degree is None and self.truncates:
            # the same lockstep default as make_plan: every device pays for
            # the max degree, so cap it at ~2 ln(mn) (decodability re-checked)
            max_degree = max(
                1, min(d, int(np.ceil(2 * np.log(max(d, 2)) + 1))))
        for attempt in range(max_resample):
            inst = self.instance(m, n, num_workers, seed=seed + attempt,
                                 **kwargs)
            if any(len(rows) != 1 for rows in inst.worker_rows):
                raise ValueError(
                    f"scheme {self.name!r} assigns multiple generator rows "
                    "per worker; it has no one-row-per-device SPMD plan")
            N = inst.num_workers
            M = inst.M.tocsr()
            degrees = np.diff(M.indptr)
            L = int(max_degree or max(1, degrees.max(initial=1)))
            cols = np.zeros((N, L), dtype=np.int32)
            weights = np.zeros((N, L), dtype=np.float32)
            Mt = np.zeros((N, d))
            for k in range(N):
                lo, hi = M.indptr[k], M.indptr[k + 1]
                take = min(hi - lo, L)
                cols[k, :take] = M.indices[lo:lo + take]
                weights[k, :take] = M.data[lo:lo + take]
                Mt[k, M.indices[lo:lo + take]] = M.data[lo:lo + take]
            # one-shot rank check at plan-construction time, not per-event
            # decode gating -- the hot-path contract does not apply here
            if np.linalg.matrix_rank(Mt) >= d:  # repro: allow(matrix-rank-hot-path)
                design = CodeDesign(m=m, n=n, num_workers=N,
                                    scheme=self.name, seed=seed + attempt)
                return CodedMatmulPlan(
                    spec=design, cols=cols, weights=weights,
                    decode=decode_matrix(Mt).astype(np.float32),
                    max_degree=L)
            if self.fixed_workers:
                break  # deterministic design: resampling cannot help
        raise RuntimeError(
            f"scheme {self.name!r}: no full-rank truncated coefficient "
            f"matrix after {max_resample} tries (max_degree={max_degree})")


_REGISTRY: dict[str, Scheme] = {}


def register_scheme(name: str, builder: Callable | None = None, *,
                    fixed_workers: bool = False, truncates: bool = False,
                    invariants: SchemeInvariants | None = None):
    """Register a scheme builder under ``name`` (usable as a decorator).

    ``invariants`` is the design's static decodability profile (recovery
    threshold kind and allowed overhead); ``repro.analysis`` validates every
    registered scheme against it, falling back to a permissive default when
    omitted.  Built-ins declare theirs in ``repro.core.schemes.INVARIANTS``.
    """

    def _register(fn):
        _REGISTRY[name] = Scheme(
            name=name, builder=fn, fixed_workers=fixed_workers,
            truncates=truncates,
            invariants=invariants or schemes_lib.INVARIANTS.get(name))
        return fn

    if builder is None:
        return _register
    _register(builder)
    return _REGISTRY[name]


def get_scheme(name: str) -> Scheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"scheme {name!r} not in {scheme_names()}") from None


def scheme_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


# ---------------------- built-in scheme registrations -----------------------
# Builders normalize to (m, n, N, *, seed, **kw); the underlying ctors live
# in repro.core.schemes and keep their positional signatures.

register_scheme("uncoded", lambda m, n: schemes_lib.uncoded(m, n),
                fixed_workers=True)
register_scheme("sparse_code",
                lambda m, n, N, *, seed=0, **kw:
                schemes_lib.sparse_code(m, n, N, seed=seed, **kw),
                truncates=True)
register_scheme("lt_code",
                lambda m, n, N, *, seed=0:
                schemes_lib.lt_code(m, n, N, seed=seed),
                truncates=True)
register_scheme("sparse_mds",
                lambda m, n, N, *, seed=0, **kw:
                schemes_lib.sparse_mds_code(m, n, N, seed=seed, **kw))
register_scheme("polynomial",
                lambda m, n, N, *, seed=0:
                schemes_lib.polynomial_code(m, n, N, seed=seed))
register_scheme("mds",
                lambda m, n, N, *, seed=0:
                schemes_lib.mds_code(m, n, N, seed=seed))
register_scheme("product",
                lambda m, n, N, *, seed=0:
                schemes_lib.product_code(m, n, N, seed=seed))
