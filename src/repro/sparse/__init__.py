from repro.sparse.blocksparse import (
    BlockELL,
    dense_to_block_ell,
    block_ell_to_dense,
    block_density,
)
