"""Block-sparse substrate: the TPU-native representation of sparsity.

The paper's CPU implementation uses unstructured CSR.  A TPU has no
gather/scatter sparse units -- its compute lives in the 128x128 MXU -- so the
faithful *adaptation* (DESIGN.md section 3) is block-granular sparsity aligned
to the MXU tile: a matrix is a grid of bs x bs tiles, and only nonzero tiles
are stored and multiplied.

Format ("block-ELL", column-block major, used by the spmm_block kernel):

  vals : (n_col_blocks, L, bs, bs)   packed nonzero tiles (zero-padded rows)
  idx  : (n_col_blocks, L)           source row-block index of each tile
  nnzb : (n_col_blocks,)             how many of the L slots are live

For C = A^T B, column-blocks of A are row-blocks of C, so each output row
block consumes exactly one (vals[rb], idx[rb]) stripe -- a clean Pallas grid.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class BlockELL:
    vals: np.ndarray   # (CB, L, bs, bs)
    idx: np.ndarray    # (CB, L) int32
    nnzb: np.ndarray   # (CB,) int32
    shape: tuple[int, int]  # dense (rows, cols)
    block_size: int

    @property
    def num_col_blocks(self) -> int:
        return self.vals.shape[0]

    @property
    def slots(self) -> int:
        return self.vals.shape[1]

    def density(self) -> float:
        rb = self.shape[0] // self.block_size
        return float(self.nnzb.sum()) / (rb * self.num_col_blocks)


def dense_to_block_ell(A: np.ndarray, block_size: int = 8,
                       slots: int | None = None) -> BlockELL:
    """Pack a dense matrix into block-ELL (keeps every nonzero tile).

    slots: pad/truncate the per-column-block tile count to this many slots
    (default: the max over column blocks).  Truncation drops the
    smallest-magnitude tiles -- used only by the approximate paths, the
    default keeps everything.

    Fully vectorized: per-column-block tile selection is one stable argsort
    on (live, energy) keys, so packing cost is O(CB * RB log RB) NumPy ops
    rather than a Python loop over column blocks.
    """
    rows, cols = A.shape
    bs = block_size
    if rows % bs or cols % bs:
        raise ValueError(f"shape {A.shape} not divisible by block_size {bs}")
    RB, CB = rows // bs, cols // bs
    tiles = A.reshape(RB, bs, CB, bs).transpose(2, 0, 1, 3)  # (CB, RB, bs, bs)
    energy = np.abs(tiles).sum(axis=(2, 3))                  # (CB, RB)
    live = energy > 0
    per_cb = live.sum(axis=1)
    L = int(slots if slots is not None else max(int(per_cb.max(initial=1)), 1))
    # live tiles first, largest energy first among them; dead tiles sort last
    order = np.argsort(np.where(live, -energy, np.inf), axis=1,
                       kind="stable")[:, :L]                 # (CB, min(L, RB))
    if L > RB:  # more slots than row blocks: pad with the dead sentinel
        order = np.pad(order, ((0, 0), (0, L - RB)), constant_values=RB)
    nnzb = np.minimum(per_cb, L).astype(np.int32)
    slot_live = np.arange(L)[None, :] < nnzb[:, None]        # (CB, L)
    # kept row-blocks in ascending order, sentinel RB pushed to the tail
    picked = np.sort(np.where(slot_live, order, RB), axis=1)
    idx = np.where(slot_live, picked, 0).astype(np.int32)
    gathered = tiles[np.arange(CB)[:, None], np.minimum(picked, RB - 1)]
    vals = np.where(slot_live[..., None, None], gathered,
                    np.zeros((), dtype=A.dtype))
    return BlockELL(vals=vals, idx=idx, nnzb=nnzb, shape=(rows, cols),
                    block_size=bs)


def block_ell_to_dense(b: BlockELL) -> np.ndarray:
    rows, cols = b.shape
    bs = b.block_size
    A = np.zeros((rows, cols), dtype=b.vals.dtype)
    for cb in range(b.num_col_blocks):
        for l in range(int(b.nnzb[cb])):
            rb = int(b.idx[cb, l])
            A[rb * bs:(rb + 1) * bs, cb * bs:(cb + 1) * bs] = b.vals[cb, l]
    return A


def block_density(A: np.ndarray, block_size: int = 8) -> float:
    """Fraction of bs x bs tiles with any nonzero -- the quantity that
    determines TPU sparse-matmul cost (not elementwise nnz)."""
    rows, cols = A.shape
    bs = block_size
    RB, CB = rows // bs, cols // bs
    tiles = A[: RB * bs, : CB * bs].reshape(RB, bs, CB, bs)
    live = np.abs(tiles).sum(axis=(1, 3)) > 0
    return float(live.mean())
