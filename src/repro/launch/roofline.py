import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

import argparse
import dataclasses
import json
import pathlib

import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get
from repro.launch.dryrun import SHAPES, cell_supported, run_cell
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

"""Roofline analysis from compiled dry-run artifacts (single-pod mesh).

HLO cost analysis counts scan/while bodies ONCE, so raw full-model numbers
undercount deep stacks.  We therefore compile *probe* variants -- the same
config at 1 and 2 layer groups, fully unrolled (and CE in 2 unrolled chunks)
-- and extrapolate:

    total(G) = probe(1) + (G - 1) * [probe(2) - probe(1)]

which is exact for flops/bytes/collectives because every group is
structurally identical.  Sequence-recurrence scans (rwkv / mamba time steps)
cannot be unrolled at 4k-500k steps; their per-step state-update flops are
added analytically (a few % of the matmul flops; see EXPERIMENTS.md).

Terms (per training/serving step, TPU v5e):
    compute_s    = HLO_flops_per_device / 197e12
    memory_s     = HLO_bytes_per_device / 819e9
    collective_s = collective_bytes_per_device (x2 for all-reduce) / 50e9
"""

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "roofline"
DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# ------------------------- kernel-level roofline -----------------------------
#
# The model-arch analysis above prices whole training/serving steps against
# the TPU v5e datasheet.  The coded-matmul KERNEL lanes (spmm_block_fused /
# spmm_block_fused_decode, DESIGN.md section 12) need the same yardstick on
# whatever host actually runs the bench -- CI is a CPU box -- so their peaks
# are *calibrated in situ*: a dense f32 matmul for peak flops, a bandwidth-
# bound elementwise pass for peak bytes/s.  Fraction-of-roofline then means
# "of what THIS machine demonstrably sustains", not of a datasheet it never
# matches, and the fused >= unfused acceptance comparison is machine-
# independent.

def machine_peaks(calibrate: bool | None = None, *, reps: int = 5) -> dict:
    """{"peak_flops", "peak_bw", "source"} of the current default backend.

    calibrate=None measures on anything that is not a TPU (where the
    datasheet constants above are the right ceiling).  Measurement is
    deliberately favorable -- big square matmul, pure streaming pass -- so
    the returned peaks are upper bounds and roofline fractions stay <= ~1.
    """
    import time

    import jax
    import jax.numpy as jnp

    if calibrate is None:
        calibrate = jax.default_backend() != "tpu"
    if not calibrate:
        return {"peak_flops": PEAK_FLOPS_BF16, "peak_bw": HBM_BW,
                "source": "datasheet-tpu-v5e"}

    def best_time(fn, *args):
        fn(*args).block_until_ready()
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn(*args).block_until_ready()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    n = 1024
    x = jnp.ones((n, n), jnp.float32)
    t_mm = best_time(jax.jit(lambda a: a @ a), x)
    peak_flops = 2.0 * n ** 3 / t_mm

    big = jnp.ones((32 * 1024 * 1024 // 4,), jnp.float32)  # 32 MB stream
    t_bw = best_time(jax.jit(lambda a: a + 1.0), big)
    peak_bw = 2.0 * big.size * 4 / t_bw                    # read + write

    return {"peak_flops": float(peak_flops), "peak_bw": float(peak_bw),
            "source": "calibrated"}


def fused_kernel_cost(*, live_tiles: int, bs: int, bt: int, mn: int, br: int,
                      fused: bool, tile_itemsize: int = 4) -> dict:
    """{"flops", "bytes"} of one worker's coded local product + decode.

    The USEFUL work is identical for both paths (same tiles, same decode
    combine); the unfused path additionally round-trips the (br, bt)
    accumulation C~ through HBM between its two launches, which is the
    whole point of the fused epilogue.  ``tile_itemsize`` prices quantized
    packs (4 f32, 2 bf16, 1 int8); B and the outputs are always f32.
    """
    flops = 2.0 * live_tiles * bs * bs * bt     # tile^T @ B-tile MACs
    flops += live_tiles * bs * bt               # per-slot weight scale
    flops += mn * br * bt                       # decode combine multiplies
    bytes_ = live_tiles * bs * bs * tile_itemsize   # packed tiles of A
    bytes_ += live_tiles * bs * bt * 4              # gathered B tiles
    bytes_ += mn * br * bt * 4                      # decode-stack write
    if not fused:
        bytes_ += 2.0 * br * bt * 4             # C~ HBM round-trip
    return {"flops": float(flops), "bytes": float(bytes_)}


def roofline_fraction(cost: dict, measured_s: float, peaks: dict) -> float:
    """Achieved fraction of this machine's roofline for the given cost.

    ideal = max(compute-bound, memory-bound) time; fraction = ideal /
    measured.  Compare paths at the SAME cost (the useful work) so the
    fraction penalizes overhead instead of crediting it with extra bytes.
    """
    ideal = max(cost["flops"] / peaks["peak_flops"],
                cost["bytes"] / peaks["peak_bw"])
    return float(ideal / max(measured_s, 1e-12))


def _probe_cfg(cfg, groups: int, enc_layers: int | None = None):
    g = cfg.group_size
    kw = {"num_layers": g * groups, "name": f"{cfg.name}-probe{groups}"}
    if cfg.encoder_layers:
        kw["encoder_layers"] = enc_layers if enc_layers is not None else 1
    return dataclasses.replace(cfg, **kw)


def _extract(rec: dict) -> dict:
    ca = rec["cost_analysis"]
    coll = rec["collectives"]["bytes"]
    # per-device collective seconds: ring all-reduce moves ~2x the payload
    coll_bytes = (coll["all-gather"] + coll["reduce-scatter"]
                  + coll["all-to-all"] + coll["collective-permute"]
                  + 2 * coll["all-reduce"])
    return {
        "flops": ca["flops_per_device"],
        "bytes": ca["bytes_per_device"],
        "coll_bytes": float(coll_bytes),
    }


def _combine(p1: dict, p2: dict, reps: int) -> dict:
    """total = p1 + (reps-1) * (p2 - p1), clamped at >= p1."""
    out = {}
    for k in p1:
        marg = max(p2[k] - p1[k], 0.0)
        out[k] = p1[k] + (reps - 1) * marg
    return out


def analytic_memory_bytes(cfg, shape: str, chips: int = 256,
                          dp: int = 16, tp: int = 16) -> float:
    """Per-device HBM traffic model (the XLA CPU backend's 'bytes accessed'
    has no fusion modeling and overestimates ~10x; this coarse analytic model
    is the headline memory term, the raw HLO number is reported alongside).

    train  : AdamW state machine (24 B/param local) + C1 passes over local
             activations (fwd+bwd+remat) + attention score traffic.
    prefill: param reads + C2 activation passes + KV-cache writes.
    decode : params read once per token step + full KV-cache read.
    """
    info = SHAPES[shape]
    import jax
    from repro.models import build
    model = build(cfg)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(model.shapes()))
    d = cfg.d_model
    L = cfg.num_layers

    if info["kind"] == "train":
        toks_local = info["batch"] * info["seq"] // dp
        param_traffic = 24.0 * n_params / chips
        # residual-stream tensors are replicated across TP; inner (ff, heads)
        # tensors are /tp and roughly cancel the extra passes -> ~40 passes
        # of (tokens_local x d) per layer covers fwd+bwd+remat
        act = 40.0 * toks_local * d * 2.0 * L
        # attention scores fwd+bwd+remat (causal ~ S^2/2), sharded dp x tp
        if not cfg.rwkv and cfg.attn_every >= 1:
            attn_layers = sum(1 for mx, _ in cfg.layer_plan()
                              if mx in ("attn", "cross", "self_cross")) * cfg.num_groups
            act += 3.0 * info["batch"] * cfg.num_heads * info["seq"] ** 2 * 2.0 \
                * attn_layers / (2.0 * chips)
        return param_traffic + act
    if info["kind"] == "prefill":
        toks_local = info["batch"] * info["seq"] // dp
        act = 14.0 * toks_local * d * 2.0 * L
        attn_layers = sum(1 for mx, _ in cfg.layer_plan()
                          if mx in ("attn", "cross", "self_cross")) * cfg.num_groups
        if not cfg.rwkv:
            act += info["batch"] * cfg.num_heads * info["seq"] ** 2 * 2.0 \
                * attn_layers / (2.0 * chips)
        return 2.0 * n_params / chips + act
    # decode: one token against the cache
    cache_bytes = 0.0
    attn_layers = sum(1 for mx, _ in cfg.layer_plan()
                      if mx in ("attn", "self_cross")) * cfg.num_groups
    cache_bytes += (2.0 * info["batch"] * info["seq"] * cfg.num_kv_heads
                    * cfg.hd * 2.0 * attn_layers) / chips
    frac_active = cfg.active_params_count() / max(cfg.params_count(), 1)
    return 2.0 * n_params * min(frac_active, 1.0) / chips + cache_bytes


def _recurrence_flops(cfg, tokens: int) -> float:
    """Analytic per-step state-update flops hidden inside sequence scans."""
    per_tok_layer = 0.0
    if cfg.rwkv:
        hs = cfg.rwkv_head_size
        H = cfg.d_model // hs
        per_tok_layer += 6.0 * H * hs * hs
    if cfg.ssm is not None:
        di = cfg.ssm.expand * cfg.d_model
        frac = sum(1 for mx, _ in cfg.layer_plan() if mx == "mamba") / cfg.group_size
        per_tok_layer += 6.0 * di * cfg.ssm.d_state * frac
    return per_tok_layer * cfg.num_layers * tokens


def analyze_cell(arch: str, shape: str, *, chips: int = 256,
                 cfg_override=None, force: bool = False,
                 opts: tuple = ()) -> dict:
    cfg = cfg_override or get(arch)
    if opts:
        cfg = cfg.with_opts(opts)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skipped", "reason": why}

    info = SHAPES[shape]
    tokens = info["batch"] * (info["seq"] if info["kind"] == "train" else
                              (info["seq"] if info["kind"] == "prefill" else 1))

    # probes: 1 and 2 layer groups, unrolled, CE in 2 big chunks
    ce = None
    if info["kind"] == "train":
        ce = (info["batch"] * info["seq"]) // 2
    probes = {}
    for gk in (1, 2):
        rec = run_cell(arch, shape, multi_pod=False, scan_unroll=True,
                       cfg_override=_probe_cfg(cfg, gk), ce_chunk=ce)
        if rec["status"] != "ok":
            return {"arch": arch, "shape": shape, "status": "error",
                    "error": rec.get("error", "probe failed")}
        probes[gk] = _extract(rec)
    total = _combine(probes[1], probes[2], cfg.num_groups)

    if cfg.encoder_layers:
        # encoder marginal: probe with 2 encoder layers at 1 group
        rec = run_cell(arch, shape, multi_pod=False, scan_unroll=True,
                       cfg_override=_probe_cfg(cfg, 1, enc_layers=2), ce_chunk=ce)
        if rec["status"] == "ok":
            enc2 = _extract(rec)
            for k in total:
                marg = max(enc2[k] - probes[1][k], 0.0)
                total[k] += (cfg.encoder_layers - 1) * marg

    # hidden recurrence flops (seq scans not unrollable)
    seq_tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    total["flops"] += _recurrence_flops(cfg, seq_tokens) / chips

    mem_model = analytic_memory_bytes(cfg, shape, chips=chips)
    compute_s = total["flops"] / PEAK_FLOPS_BF16
    memory_s = mem_model / HBM_BW
    memory_s_hlo_raw = total["bytes"] / HBM_BW
    coll_s = total["coll_bytes"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6*N_active*D train, 2*N_active*D inference
    import jax
    from repro.models import build
    model = build(cfg)
    shapes_tree = model.shapes()
    n_total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes_tree))
    frac_active = cfg.active_params_count() / max(cfg.params_count(), 1)
    n_active = n_total * min(frac_active, 1.0)
    mult = 6.0 if info["kind"] == "train" else 2.0
    model_flops = mult * n_active * tokens
    hlo_total = total["flops"] * chips
    ratio = model_flops / max(hlo_total, 1.0)

    # step time bound & roofline fraction
    step_bound = max(terms.values())
    mfu_bound = (model_flops / chips / PEAK_FLOPS_BF16) / max(step_bound, 1e-12)

    return {
        "arch": arch, "shape": shape, "status": "ok", "chips": chips,
        "tokens_per_step": tokens,
        "per_device": total,
        "terms": terms,
        "memory_s_hlo_raw": memory_s_hlo_raw,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_ratio": ratio,
        "roofline_fraction_bound": mfu_bound,
        "n_params": n_total,
        "n_active": n_active,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all", choices=["all"] + list(SHAPES))
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list: fused_ce,moe_local_dispatch,onehot_cache"
                         " (writes <arch>__<shape>__<opts>.json)")
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    suffix = ("__" + "+".join(opts)) if opts else ""
    for arch in archs:
        for shape in shapes:
            path = OUT_DIR / f"{arch}__{shape}{suffix}.json"
            if path.exists() and not args.force:
                print(f"[roofline] {arch}/{shape}{suffix}: cached")
                continue
            try:
                rec = analyze_cell(arch, shape, opts=opts)
                rec["opts"] = list(opts)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "error": f"{type(e).__name__}: {e}"}
            path.write_text(json.dumps(rec, indent=1))
            if rec["status"] == "ok":
                t = rec["terms"]
                print(f"[roofline] {arch}/{shape}: compute={t['compute_s']*1e3:.2f}ms "
                      f"memory={t['memory_s']*1e3:.2f}ms "
                      f"coll={t['collective_s']*1e3:.2f}ms "
                      f"dom={rec['dominant']} useful={rec['useful_ratio']:.2f} "
                      f"roofline<={rec['roofline_fraction_bound']:.2%}", flush=True)
            else:
                print(f"[roofline] {arch}/{shape}: {rec['status']} "
                      f"{rec.get('error', rec.get('reason', ''))[:120]}", flush=True)


if __name__ == "__main__":
    main()
