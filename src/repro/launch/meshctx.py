"""Process-global mesh context.

Model code calls ``maybe_shard(x, 'data', None, 'model')`` to attach GSPMD
sharding constraints.  When no mesh is active (CPU smoke tests, single
device) the call is a no-op, so the same model code runs everywhere.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CURRENT_MESH: jax.sharding.Mesh | None = None


def set_mesh(mesh: jax.sharding.Mesh | None) -> None:
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


def get_mesh() -> jax.sharding.Mesh | None:
    return _CURRENT_MESH


@contextlib.contextmanager
def use_mesh(mesh: jax.sharding.Mesh):
    prev = _CURRENT_MESH
    set_mesh(mesh)
    try:
        yield mesh
    finally:
        set_mesh(prev)


def spec(*axes) -> P:
    """PartitionSpec, dropping axes the active mesh does not have.

    'dp' is an alias for the full data-parallel product: ('pod', 'data') on a
    multi-pod mesh, 'data' on a single-pod mesh, dropped with no mesh.
    """
    mesh = get_mesh()
    if mesh is None:
        return P()
    names = set(mesh.axis_names)
    out = []
    for a in axes:
        if a == "dp":
            dp = tuple(x for x in ("pod", "data") if x in names)
            out.append(dp if len(dp) > 1 else (dp[0] if dp else None))
        elif a is None:
            out.append(None)
        elif isinstance(a, tuple):
            kept = tuple(x for x in a if x in names)
            out.append(kept if kept else None)
        else:
            out.append(a if a in names else None)
    return P(*out)


def maybe_shard(x, *axes):
    """with_sharding_constraint when a mesh is active, else identity."""
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec(*axes)))


def named_sharding(*axes) -> NamedSharding | None:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*axes))
