"""Training driver: config-driven, fault-tolerant, elastic.

Production behaviors demonstrated end-to-end on CPU (and directly usable on a
real mesh by launching one process per host with jax.distributed):

* pjit train step with FSDP ('data') x TP ('model') shardings;
* periodic async checkpointing + resume-from-latest on restart;
* coded checkpoint redundancy (--coded-ckpt): restore from any K of N shards;
* --simulate-failure: kills the process at a step to exercise restart;
* --elastic: on restart, rebuild the mesh from surviving device count.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --reduced --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.launch import meshctx
from repro.launch.mesh import make_mesh_for_devices
from repro.models import build
from repro.training import checkpoint as ckpt_lib
from repro.training.data import SyntheticCorpus
from repro.training.optimizer import AdamW, cosine_warmup_schedule
from repro.training.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-feasible)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--coded-ckpt", action="store_true",
                    help="also write sparse-code erasure shards")
    ap.add_argument("--opt-dtype", default="float32", choices=["float32", "bfloat16"])
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="exit(17) at this step (restart test)")
    ap.add_argument("--elastic", action="store_true",
                    help="build mesh from available devices (TP capped)")
    ap.add_argument("--model-parallel", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build(cfg)

    ndev = len(jax.devices())
    mesh = None
    if ndev > 1 or args.elastic:
        mesh = make_mesh_for_devices(ndev, args.model_parallel or min(2, ndev))
        meshctx.set_mesh(mesh)
        print(f"[train] mesh {dict(mesh.shape)}")

    opt = AdamW(lr=cosine_warmup_schedule(args.lr, args.warmup, args.steps),
                state_dtype=jnp.dtype(args.opt_dtype))
    step_fn = make_train_step(model, opt)
    if mesh is not None:
        pspecs = jax.tree.map(lambda s: NamedSharding(mesh, meshctx.spec(*s)),
                              model.specs(), is_leaf=lambda x: isinstance(x, tuple))
        ospecs = {"m": pspecs, "v": pspecs, "count": NamedSharding(mesh, P())}
        step_fn = jax.jit(step_fn, in_shardings=(pspecs, ospecs, None),
                          out_shardings=(pspecs, ospecs, None), donate_argnums=(0, 1))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    ckpt_dir = pathlib.Path(args.ckpt_dir) / cfg.name
    start = ckpt_lib.latest_step(ckpt_dir)
    params = model.init(jax.random.key(0), jnp.float32)
    opt_state = opt.init(params)
    if start is not None:
        params, opt_state, start = ckpt_lib.restore_checkpoint(
            ckpt_dir, params, opt_state)
        print(f"[train] resumed from step {start}")
    else:
        start = 0
        print(f"[train] fresh start; params="
              f"{sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params)):,}")

    corpus = SyntheticCorpus(cfg, args.batch, args.seq, seed=0)
    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in corpus.make_batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {loss:8.4f} gnorm {gn:8.3f} "
                  f"({dt:6.1f}s)", flush=True)
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            saver.save(step + 1, params, opt_state)
            if args.coded_ckpt:
                ckpt_lib.save_coded_checkpoint(ckpt_dir, step + 1, params)
        if args.simulate_failure and step + 1 == args.simulate_failure:
            saver.wait()
            print(f"[train] SIMULATED FAILURE at step {step + 1}", flush=True)
            sys.exit(17)
    saver.wait()
    print(f"[train] done: {args.steps} steps in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
