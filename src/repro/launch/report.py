"""Format experiments/dryrun + experiments/roofline JSONs as markdown tables.

  PYTHONPATH=src python -m repro.launch.report [--dryrun|--roofline|--perf]
"""

from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[3] / "experiments"


def _load(d: pathlib.Path):
    recs = []
    if not d.is_dir():
        return recs
    for p in sorted(d.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def _gb(x: float) -> str:
    return f"{x/2**30:.2f}"


def dryrun_table(root: pathlib.Path | str | None = None) -> str:
    """Markdown table of dryrun records under ``root`` (default: the
    checked-in experiments dir).  Families that errored render as rows
    carrying their error string; an empty/missing record dir renders an
    explicit placeholder row rather than a silently bare header."""
    recs = _load(pathlib.Path(root) if root is not None else ROOT / "dryrun")
    lines = [
        "| arch | shape | mesh | status | compile_s | flops/dev | HLO bytes/dev | coll bytes/dev | arg GiB/dev | temp GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    if not recs:
        lines.append("| (no dryrun records -- run "
                     "`PYTHONPATH=src python -m repro.launch.dryrun`) "
                     "| | | | | | | | | |")
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r['status']}: {r.get('reason', r.get('error', ''))[:60]} "
                         "| | | | | | |")
            continue
        ca = r["cost_analysis"]
        ma = r.get("memory_analysis", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']} "
            f"| {ca['flops_per_device']:.3g} | {ca['bytes_per_device']:.3g} "
            f"| {r['collectives']['total_bytes']:.3g} "
            f"| {_gb(ma.get('argument_bytes', 0))} | {_gb(ma.get('temp_bytes', 0))} |")
    return "\n".join(lines)


def roofline_table(include_variants: bool = False,
                   root: pathlib.Path | str | None = None) -> str:
    recs = _load(pathlib.Path(root) if root is not None else ROOT / "roofline")
    lines = [
        "| arch | shape | opts | compute_s | memory_s | collective_s | dominant "
        "| MODEL_FLOPS | HLO_FLOPS | useful | roofline<= |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        opts = "+".join(r.get("opts", [])) or "baseline"
        if not include_variants and opts != "baseline":
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {opts} | "
                         f"{r['status']}: {r.get('reason', r.get('error',''))[:50]} "
                         "| | | | | | |")
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {opts} "
            f"| {t['compute_s']:.4g} | {t['memory_s']:.4g} | {t['collective_s']:.4g} "
            f"| {r['dominant'].replace('_s','')} "
            f"| {r['model_flops']:.3g} | {r['hlo_flops_total']:.3g} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction_bound']:.2%} |")
    return "\n".join(lines)


def perf_table(root: pathlib.Path | str | None = None) -> str:
    """Baseline vs optimized, per cell that has variants."""
    recs = _load(pathlib.Path(root) if root is not None else ROOT / "roofline")
    by_cell: dict = {}
    for r in recs:
        if r["status"] != "ok":
            continue
        key = (r["arch"], r["shape"])
        by_cell.setdefault(key, {})["+".join(r.get("opts", [])) or "baseline"] = r
    lines = [
        "| cell | variant | compute_s | memory_s | collective_s | dominant | step bound | vs baseline |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), variants in sorted(by_cell.items()):
        if len(variants) < 2:
            continue
        base = variants.get("baseline")
        base_bound = max(base["terms"].values()) if base else None
        for name, r in sorted(variants.items(), key=lambda kv: kv[0] != "baseline"):
            t = r["terms"]
            bound = max(t.values())
            rel = f"{base_bound / bound:.2f}x" if base_bound and name != "baseline" else "--"
            lines.append(
                f"| {arch}/{shape} | {name} | {t['compute_s']:.4g} | {t['memory_s']:.4g} "
                f"| {t['collective_s']:.4g} | {r['dominant'].replace('_s','')} "
                f"| {bound:.4g} | {rel} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--perf", action="store_true")
    args = ap.parse_args()
    if args.dryrun or not (args.roofline or args.perf):
        print("## Dry-run\n")
        print(dryrun_table())
    if args.roofline:
        print("## Roofline (single-pod baselines)\n")
        print(roofline_table())
    if args.perf:
        print("## Perf (baseline vs optimized)\n")
        print(perf_table())


if __name__ == "__main__":
    main()
