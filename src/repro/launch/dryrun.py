import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")  # silence SPMD chatter

# --- everything below may import jax (device count is pinned above) ---------

import argparse
import dataclasses
import json
import pathlib
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import ARCHS, get
from repro.launch import meshctx
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.serving.serve_step import make_prefill_step
from repro.training.data import input_specs
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we jit the production step function with explicit in/out
shardings on the production mesh, .lower().compile() it, and record
memory_analysis / cost_analysis / the collective mix parsed from the
compiled HLO.  Failures here are sharding bugs in the framework.

Roofline probes: scan bodies are counted ONCE by HLO cost analysis, so for
the roofline we also compile fully-unrolled shallow variants (1 and 2 layer
groups; encoder depths likewise for enc-dec) and extrapolate exact per-group
marginal costs.  Probes run on the single-pod mesh only (the roofline table
is single-pod per the assignment).
"""

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32_768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32_768, batch=128, kind="decode"),
    "long_500k": dict(seq=524_288, batch=1, kind="decode"),
}

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def cell_supported(cfg, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (skip per spec)"
    return True, ""


# ------------------------- collective byte parsing --------------------------

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s32|u32|s8|u8|pred|s64|u64)\[([0-9,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all typed shapes in an HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind output bytes from compiled HLO text.

    Counts each instruction once (scan bodies are therefore single-counted --
    the roofline probes correct for that by extrapolating unrolled shallow
    models instead of trusting these raw numbers on deep scans).
    """
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # "%name = TYPE all-reduce(...)" -- take lhs type bytes
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", stripped)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-start"):
            op = op[:-6]
        if op in COLLECTIVES:
            out[op] += _shape_bytes(m.group(1))
            counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


# ------------------------------ cell builders --------------------------------

def _shardings_for(tree_specs, mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, meshctx.spec(*spec) if isinstance(spec, tuple) else spec),
        tree_specs, is_leaf=lambda x: isinstance(x, tuple))


def _batch_shardings(batch_struct, mesh):
    def spec_for(path_leaf):
        if path_leaf.ndim == 2:
            return NamedSharding(mesh, meshctx.spec("dp", None))
        return NamedSharding(mesh, meshctx.spec("dp", None, None))
    return jax.tree.map(spec_for, batch_struct)


def _serving_layout(param_shardings, mesh):
    """Decode-time weight layout (opt_serving_layout).

    At one token per step there is no batch to amortize FSDP: GSPMD
    all-gathers every data-sharded weight each step (measured as the dominant
    long_500k/decode collective).  Re-lay the weights so the 'data' axis
    shards a *contraction* (or output) dimension instead: the per-token
    matmul then emits a tiny partial that one psum fixes, and no weight ever
    moves.  KV caches keep the 'model' axis (sequence-sharded flash-decode).
    """
    def rewrite(path, sh):
        names = [getattr(p, "key", None) for p in path]
        leaf = names[-1] if names else None
        def ns(*axes):
            return NamedSharding(mesh, meshctx.spec(*axes))
        if leaf in ("w_gate", "w_up"):
            if len(sh.spec) == 4:      # MoE experts (G, E, d, ff)
                return ns(None, "model", None, "data")
            return ns(None, None, "data")          # dense MLP (G, d, ff)
        if leaf == "w_down":
            if len(sh.spec) == 4:      # (G, E, ff, d)
                return ns(None, "model", "data", None)
            return ns(None, "data", None)          # (G, ff, d)
        if leaf in ("wq", "wk", "wv", "wr", "wg"):
            return ns(None, None, "data")          # out-dim over data
        if leaf == "wo":
            return ns(None, "data", None)          # in-dim over data -> psum
        if leaf in ("in_proj", "x_proj", "dt_proj", "out_proj"):
            # mamba: keep d_inner on 'model' (state layout), drop 'data'
            return NamedSharding(mesh, PSpecDrop(sh.spec, "data"))
        if leaf in ("embed", "head"):
            return sh                               # vocab stays model-sharded
        # everything else: drop 'data' (replicate small tensors)
        return NamedSharding(mesh, PSpecDrop(sh.spec, "data"))

    return jax.tree_util.tree_map_with_path(rewrite, param_shardings)


def PSpecDrop(spec, axis):
    out = []
    for entry in spec:
        if entry == axis:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != axis)
            out.append(kept if kept else None)
        else:
            out.append(entry)
    return P(*out)


def _sanitize(structs, shardings, mesh):
    """Explicit pjit in_shardings require exact divisibility (constraints
    would pad).  Replicate any dimension whose size does not divide its mesh
    axes -- the production choice for odd head counts / vocab sizes / short
    memory axes (waste surfaces in the roofline ratio)."""
    def fix(struct, sh):
        if not isinstance(sh, NamedSharding):
            return sh
        spec = sh.spec
        new = []
        for dim, axes in zip(struct.shape, tuple(spec) + (None,) * (len(struct.shape) - len(spec))):
            if axes is None:
                new.append(None)
                continue
            names = axes if isinstance(axes, tuple) else (axes,)
            total = 1
            for nm in names:
                total *= mesh.shape[nm]
            new.append(axes if dim % total == 0 else None)
        return NamedSharding(mesh, P(*new))

    return jax.tree.map(fix, structs, shardings)


def build_cell(cfg, shape_name: str, mesh, scan_unroll=False, ce_chunk=None):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    info = SHAPES[shape_name]
    model = build(cfg)
    model.scan_unroll = scan_unroll
    model.ce_chunk = ce_chunk
    param_structs = model.shapes(jnp.bfloat16)
    param_shardings = _sanitize(param_structs,
                                _shardings_for(model.specs(), mesh), mesh)

    batch_struct = input_specs(cfg, info["batch"], info["seq"], kind=info["kind"])
    batch_shardings = _sanitize(batch_struct,
                                _batch_shardings(batch_struct, mesh), mesh)

    if info["kind"] == "train":
        opt = AdamW(lr=1e-4, state_dtype=jnp.float32)
        opt_struct = jax.eval_shape(opt.init, param_structs)
        opt_shardings = {
            "m": param_shardings, "v": param_shardings,
            "count": NamedSharding(mesh, P()),
        }
        step = make_train_step(model, opt)
        args = (param_structs, opt_struct, batch_struct)
        in_sh = (param_shardings, opt_shardings, batch_shardings)
        out_sh = (param_shardings, opt_shardings,
                  jax.tree.map(lambda _: NamedSharding(mesh, P()),
                               {"loss": 0, "grad_norm": 0}))
        return step, args, in_sh, out_sh

    if info["kind"] == "prefill":
        step = make_prefill_step(model, max_seq=info["seq"])
        args = (param_structs, batch_struct)
        in_sh = (param_shardings, batch_shardings)
        return step, args, in_sh, None

    # decode: one token against a cache of length seq
    if getattr(cfg, "opt_serving_layout", False):
        param_shardings = _sanitize(
            param_structs, _serving_layout(param_shardings, mesh), mesh)
    cache_struct = jax.eval_shape(
        lambda: model.init_cache(info["batch"], info["seq"], jnp.bfloat16))
    cache_shardings = _sanitize(
        cache_struct,
        jax.tree.map(lambda sp: NamedSharding(mesh, sp),
                     model.cache_specs(cache_struct)),
        mesh)
    tok_struct = jax.ShapeDtypeStruct((info["batch"], 1), jnp.int32)
    tok_sharding = _sanitize(tok_struct,
                             NamedSharding(mesh, meshctx.spec("dp", None)), mesh)

    def decode_fn(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache

    args = (param_structs, cache_struct, tok_struct)
    in_sh = (param_shardings, cache_shardings, tok_sharding)
    out_sh = (_sanitize(jax.ShapeDtypeStruct((info["batch"],), jnp.int32),
                        NamedSharding(mesh, meshctx.spec("dp")), mesh),
              cache_shardings)
    return decode_fn, args, in_sh, out_sh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             scan_unroll=False, cfg_override=None, ce_chunk=None,
             mesh=None) -> dict:
    cfg = cfg_override or get(arch)
    ok, why = cell_supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    record = {"arch": arch, "shape": shape_name,
              "mesh": "multi" if multi_pod else "single",
              "mesh_shape": dict(mesh.shape), "status": "ok",
              # the coded-matmul deployment this cell would run with
              # (registry-validated at ArchConfig construction)
              "coded": {"scheme": cfg.coded.scheme,
                        "backend": cfg.coded.backend,
                        "out_sharded": cfg.coded.out_sharded}}
    with meshctx.use_mesh(mesh):
        fn, args, in_sh, out_sh = build_cell(cfg, shape_name, mesh,
                                             scan_unroll=scan_unroll,
                                             ce_chunk=ce_chunk)
        t0 = time.time()
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        record["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 2)

        ca = compat.cost_analysis(compiled)
        record["cost_analysis"] = {
            "flops_per_device": float(ca.get("flops", -1)),
            "bytes_per_device": float(ca.get("bytes accessed", -1)),
            "transcendentals": float(ca.get("transcendentals", 0)),
        }
        ma = compiled.memory_analysis()
        if ma is not None:
            record["memory_analysis"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_bytes_est": int(ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
            }
        txt = compiled.as_text()
        record["collectives"] = collective_bytes(txt)
        record["hlo_chars"] = len(txt)
    return record


def sweep_cell(arch: str, shape: str, multi_pod: bool, outdir: pathlib.Path,
               force: bool = False, mesh=None, cfg_override=None,
               verbose: bool = False) -> dict:
    """Run one cell and persist its record (ok, skipped, or error).

    A family that fails to lower/compile is surfaced as an ``error`` record
    carrying the exception string -- the report renders it as a table row
    instead of the family silently vanishing from the sweep.

    The on-disk cache is keyed by (arch, shape, mesh kind) only, so a
    ``mesh``/``cfg_override`` call is never served from (or mixed into a
    later read of) the cache under a key describing a different config: it
    always recomputes and overwrites.  Cache hits are marked ``cached``.
    """
    tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
    path = pathlib.Path(outdir) / f"{tag}.json"
    ad_hoc = mesh is not None or cfg_override is not None
    if path.exists() and not force and not ad_hoc:
        return dict(json.loads(path.read_text()), cached=True)
    if verbose:
        print(f"[dryrun] {tag}: lowering...", flush=True)
    try:
        rec = run_cell(arch, shape, multi_pod, mesh=mesh,
                       cfg_override=cfg_override)
    except Exception as e:  # noqa: BLE001 -- report and continue sweep
        rec = {"arch": arch, "shape": shape,
               "mesh": "multi" if multi_pod else "single",
               "status": "error", "error": f"{type(e).__name__}: {e}"}
    path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all"] + list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--force", action="store_true", help="recompute existing")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                rec = sweep_cell(arch, shape, multi, outdir, force=args.force,
                                 verbose=True)
                if rec.get("cached"):
                    print(f"[dryrun] {tag}: cached")
                    continue
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" compile={rec['compile_s']}s "
                             f"flops/dev={rec['cost_analysis']['flops_per_device']:.3g} "
                             f"coll={rec['collectives']['total_bytes']:.3g}B")
                elif status == "error":
                    failures += 1
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
    print(f"[dryrun] done, {failures} failures")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
