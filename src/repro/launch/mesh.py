"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run pins the device count via XLA_FLAGS
before any jax import; tests and benches keep the default single device).
"""

from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    single pod : (16, 16)    axes (data, model)  -- 256 chips (TPU v5e pod)
    multi pod  : (2, 16, 16) axes (pod, data, model) -- 512 chips, the 'pod'
                 axis is pure data parallelism across ICI-disconnected pods
                 (DCN), which is also the granularity of the coded
                 fault-tolerance story (decode a step from K of N pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=compat.auto_axis_types(len(axes)))


def make_mesh_for_devices(n: int, model_parallel: int = None):
    """Elastic variant: whatever devices survive, keep TP fixed and shrink
    the data axis (used by train.py --elastic restarts)."""
    tp = model_parallel or min(16, n)
    if n % tp:
        raise ValueError(f"{n} devices not divisible by model_parallel={tp}")
    return compat.make_mesh((n // tp, tp), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))


# Hardware constants for the roofline (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-chip useful bound)
CHIPS_PER_POD = 256
