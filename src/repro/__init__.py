"""repro: Coded Sparse Matrix Multiplication (Wang, Liu, Shroff 2018) as a
production-grade JAX training/inference framework.

Layers:
  repro.coded     -- THE public coded-matmul API: scheme registry,
                     CodedMatmulConfig, CodedOp (plan -> bind -> apply)
  repro.core      -- the paper's sparse code (degree design, encoder, hybrid decoder)
  repro.sparse    -- block-sparse substrate (host + JAX)
  repro.runtime   -- master/worker execution with straggler injection
  repro.models    -- 10 assigned LM architectures (dense/GQA/MoE/SSM/hybrid/enc-dec/VLM)
  repro.training  -- optimizer, train_step, data, coded checkpointing, compression
  repro.serving   -- KV cache, prefill/decode steps
  repro.kernels   -- Pallas TPU kernels (block-sparse SpMM, fused coded accumulation)
  repro.launch    -- production mesh, multi-pod dry-run, roofline, train/serve drivers

The names in ``__all__`` resolve lazily (PEP 562): ``import repro`` stays
dependency-free, and jax loads only when a jax-backed symbol (``CodedOp``
and friends) is actually touched -- after the caller has set XLA_FLAGS.
"""

__version__ = "1.1.0"

__all__ = [
    "CodedMatmulConfig",
    "CodedOp",
    "Scheme",
    "from_plan",
    "get_scheme",
    "plan",
    "register_scheme",
    "scheme_names",
    "run_device_job",
]

# symbol -> home module (all resolved lazily)
_EXPORTS = {
    "CodedMatmulConfig": "repro.coded",
    "CodedOp": "repro.coded",
    "Scheme": "repro.coded",
    "from_plan": "repro.coded",
    "get_scheme": "repro.coded",
    "plan": "repro.coded",
    "register_scheme": "repro.coded",
    "scheme_names": "repro.coded",
    "run_device_job": "repro.runtime",
}


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)


def __dir__():
    return sorted(set(globals()) | set(__all__))
