"""repro: Coded Sparse Matrix Multiplication (Wang, Liu, Shroff 2018) as a
production-grade JAX training/inference framework.

Layers:
  repro.core      -- the paper's sparse code (degree design, encoder, hybrid decoder)
  repro.sparse    -- block-sparse substrate (host + JAX)
  repro.runtime   -- master/worker execution with straggler injection
  repro.models    -- 10 assigned LM architectures (dense/GQA/MoE/SSM/hybrid/enc-dec/VLM)
  repro.training  -- optimizer, train_step, data, coded checkpointing, compression
  repro.serving   -- KV cache, prefill/decode steps
  repro.kernels   -- Pallas TPU kernels (block-sparse SpMM, fused coded accumulation)
  repro.launch    -- production mesh, multi-pod dry-run, roofline, train/serve drivers
"""

__version__ = "1.0.0"
