"""CLI driver: ``python -m repro.analysis [--strict] [--json PATH] [--only L]``.

Runs the three analysis layers (lint -> schemes -> jaxpr, cheapest first),
aggregates their findings into one ``Report``, prints human-readable
``file:line`` findings, and exits nonzero on violations:

* exit 1 -- error findings (or, under ``--strict``, any warning);
* exit 2 -- a requested layer checked zero units (a vacuous pass is a fail).

The jaxpr layer stages every registered scheme through the real CodedOp
path, which needs an 8-device mesh; the CLI provisions host devices via
XLA_FLAGS *before* jax is first imported, so run it as its own process
(exactly how the CI gate invokes it).
"""

from __future__ import annotations

import argparse
import os
import sys

LAYERS = ("lint", "schemes", "jaxpr")


def _provision_host_devices(count: int = 8) -> None:
    """Make the jaxpr layer's mesh possible on a CPU host.

    Must run before the first jax import; if jax is somehow already in,
    leave the environment alone -- the layer itself degrades to a coverage
    warning when devices are short.
    """
    if "jax" in sys.modules:  # pragma: no cover - defensive
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={count}".strip())
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant checks: code schemes, staged jaxprs, "
                    "repo contracts")
    parser.add_argument("--strict", action="store_true",
                        help="treat warnings as failures (the CI gate)")
    parser.add_argument("--json", metavar="PATH", default=None,
                        help="also write the full report as JSON ('-' for "
                             "stdout)")
    parser.add_argument("--only", action="append", choices=LAYERS,
                        default=None, metavar="LAYER",
                        help="run only this layer (repeatable; default all)")
    args = parser.parse_args(argv)
    layers = tuple(args.only) if args.only else LAYERS
    # before ANY layer: the schemes layer pulls in jax transitively (pack
    # checks import coded_matmul), and XLA_FLAGS must precede jax init
    if "jaxpr" in layers:
        _provision_host_devices()

    from repro.analysis.findings import Report

    report = Report()
    if "lint" in layers:
        from repro.analysis.lint import run_lint

        findings, files = run_lint()
        report.extend(findings)
        report.checked["lint"] = files
    if "schemes" in layers:
        from repro.analysis.schemes import run_scheme_checks

        findings, schemes = run_scheme_checks()
        report.extend(findings)
        report.checked["schemes"] = schemes
    if "jaxpr" in layers:
        from repro.analysis.jaxpr_check import run_jaxpr_checks

        findings, programs = run_jaxpr_checks()
        report.extend(findings)
        report.checked["jaxpr"] = programs

    if args.json == "-":
        print(report.to_json())
    else:
        print(report.render())
        if args.json:
            with open(args.json, "w") as fh:
                fh.write(report.to_json() + "\n")
    return report.exit_code(strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())
