"""Finding/report model shared by every analysis layer.

A *finding* is one violated (or suspicious) invariant, located as precisely
as the layer can manage: lint findings carry the offending source line,
scheme findings point at the registered builder, jaxpr findings at the
staging entry point.  The CLI (``python -m repro.analysis``) aggregates
findings from all layers into one ``Report`` and derives the process exit
code from it, so CI needs no knowledge of the individual checkers.

Severity policy: ``error`` findings always fail the run; ``warning``
findings fail only under ``--strict`` (the CI gate runs strict, so a
warning is "fix it in this PR", not "ignore it forever").
"""

from __future__ import annotations

import dataclasses
import json

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant, with a file:line anchor."""

    rule: str        # e.g. "compat-boundary", "recovery-threshold"
    severity: str    # ERROR | WARNING
    path: str        # repo-relative path of the anchor
    line: int        # 1-based; 0 when the finding has no single line
    message: str
    layer: str       # "lint" | "schemes" | "jaxpr"

    def __post_init__(self):
        if self.severity not in (ERROR, WARNING):
            raise ValueError(f"severity must be error|warning, "
                             f"got {self.severity!r}")

    def location(self) -> str:
        return f"{self.path}:{self.line}" if self.line else self.path

    def render(self) -> str:
        return (f"{self.location()}: [{self.layer}/{self.rule}] "
                f"{self.severity}: {self.message}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Report:
    """All findings of one analysis run, plus what was actually checked.

    ``checked`` counts per layer (files linted, schemes validated, programs
    verified) guard against the silent-skip failure mode: a run that found
    nothing because it *checked* nothing must not read as a pass, so
    ``exit_code`` also fails when a requested layer reports zero units.
    """

    findings: list[Finding] = dataclasses.field(default_factory=list)
    checked: dict[str, int] = dataclasses.field(default_factory=dict)

    def extend(self, findings) -> None:
        self.findings.extend(findings)

    def count(self, severity: str) -> int:
        return sum(1 for f in self.findings if f.severity == severity)

    def exit_code(self, strict: bool = False) -> int:
        if self.count(ERROR):
            return 1
        if strict and self.count(WARNING):
            return 1
        if any(n == 0 for n in self.checked.values()):
            return 2  # a requested layer checked nothing: not a real pass
        return 0

    def as_dict(self) -> dict:
        return {
            "findings": [f.as_dict() for f in self.findings],
            "checked": dict(self.checked),
            "errors": self.count(ERROR),
            "warnings": self.count(WARNING),
        }

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.as_dict(), indent=2, sort_keys=True, **kwargs)

    def render(self) -> str:
        lines = [f.render() for f in sorted(
            self.findings, key=lambda f: (f.layer, f.path, f.line, f.rule))]
        units = ", ".join(f"{k}={v}" for k, v in sorted(self.checked.items()))
        lines.append(f"repro.analysis: {self.count(ERROR)} error(s), "
                     f"{self.count(WARNING)} warning(s) [{units}]")
        return "\n".join(lines)
