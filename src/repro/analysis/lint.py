"""AST-based repo contract linter (the third analysis layer).

The contracts checked here exist so that structural properties the rest of
the system relies on cannot rot silently:

* ``compat-boundary`` -- every version-gated JAX API (``jax.experimental.*``,
  ``jax.shard_map``, ``jax.make_mesh``, ``jax.sharding.AxisType``,
  ``jax.lax.psum_scatter``) is accessed only through ``repro/compat.py``
  (DESIGN.md section 4).  The single exception is ``jax.experimental.pallas``
  inside ``kernels/`` -- the Pallas namespace is the kernel substrate itself,
  not a shimmed API, and compat deliberately does not wrap it.
* ``jax-free-module`` -- modules that declare themselves importable before
  XLA_FLAGS are set (``core/coded_backends.py``, ``coded/config.py``,
  ``core/encoder.py``, ``coded/registry.py``) must not import jax at module
  scope.  Function-local (lazy) imports are fine; that is the sanctioned
  pattern.
* ``matrix-rank-hot-path`` -- ``np.linalg.matrix_rank`` is O(rows * mn^2)
  per call; inside ``runtime/`` and ``coded/`` the per-event decodability
  contract is ``core.decoder.IncrementalRankTracker``.  Legitimate one-shot
  uses (plan construction in ``coded/registry.py``) carry an inline waiver.
* ``no-deprecated-surface`` -- no internal caller of the legacy
  ``coded_matmul`` shim: ``repro`` code must use ``repro.coded`` (CI runs
  pytest with DeprecationWarning-as-error, but that only covers executed
  paths; this rule covers every import site statically).

Waivers: append ``# repro: allow(<rule>)`` to the offending line (or put it
on its own line directly above).  A waiver that suppresses nothing is itself
an ``unused-waiver`` error, so stale waivers cannot accumulate.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Iterator

from repro.analysis.findings import ERROR, Finding

WAIVER_RE = re.compile(r"#\s*repro:\s*allow\(([a-z0-9_-]+)\)")

#: version-gated top-level JAX APIs that must route through repro.compat
VERSION_GATED_ATTRS = (
    "jax.shard_map",
    "jax.make_mesh",
    "jax.sharding.AxisType",
    "jax.lax.psum_scatter",
)


@dataclasses.dataclass(frozen=True)
class LintConfig:
    """Which files each contract applies to (paths relative to the lint
    root, posix-style).  The defaults describe the real repo layout; tests
    point the fields at fixture trees instead."""

    compat_module: str = "compat.py"
    pallas_allowed_dirs: tuple[str, ...] = ("kernels",)
    jax_free_modules: tuple[str, ...] = (
        "core/coded_backends.py",
        "coded/config.py",
        "core/encoder.py",
        "coded/registry.py",
        "serving/scheduler.py",
        "serving/loadgen.py",
    )
    hot_path_dirs: tuple[str, ...] = ("runtime", "coded")
    deprecated_module: str = "core/coded_matmul.py"
    deprecated_name: str = "coded_matmul"


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` attribute chain as a string, or None if it is not one."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _under(rel: str, dirs: tuple[str, ...]) -> bool:
    return any(rel == d or rel.startswith(d + "/") for d in dirs)


# ------------------------------ rule checkers -------------------------------
# Each checker: (rel_path, tree, config) -> Iterator[(rule, line, message)].

def check_compat_boundary(rel: str, tree: ast.AST,
                          cfg: LintConfig) -> Iterator[tuple[str, int, str]]:
    if rel == cfg.compat_module:
        return
    pallas_ok = _under(rel, cfg.pallas_allowed_dirs)

    def experimental_violation(modname: str) -> bool:
        if not modname.startswith("jax.experimental"):
            return False
        if pallas_ok and modname.startswith("jax.experimental.pallas"):
            return False
        return True

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if experimental_violation(alias.name):
                    yield ("compat-boundary", node.lineno,
                           f"import of {alias.name!r}: version-gated JAX "
                           "APIs live in repro.compat only")
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "jax.experimental":
                # `from jax.experimental import pallas` resolves per-name
                for alias in node.names:
                    if experimental_violation(f"jax.experimental.{alias.name}"):
                        yield ("compat-boundary", node.lineno,
                               f"import of jax.experimental.{alias.name}: "
                               "version-gated JAX APIs live in repro.compat "
                               "only")
            elif experimental_violation(mod):
                yield ("compat-boundary", node.lineno,
                       f"import from {mod!r}: version-gated JAX APIs live "
                       "in repro.compat only")
        elif isinstance(node, ast.Attribute):
            name = _dotted(node)
            if name is None:
                continue
            if experimental_violation(name):
                yield ("compat-boundary", node.lineno,
                       f"use of {name}: version-gated JAX APIs live in "
                       "repro.compat only")
            elif name in VERSION_GATED_ATTRS:
                yield ("compat-boundary", node.lineno,
                       f"use of {name}: call the repro.compat wrapper "
                       "instead (DESIGN.md section 4)")


def _module_scope_stmts(tree: ast.Module) -> Iterator[ast.stmt]:
    """Top-level statements, descending into module-level if/try bodies
    (those still execute at import time) but not into defs/classes."""
    stack = list(tree.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.If, ast.Try)):
            for field in ("body", "orelse", "finalbody", "handlers"):
                for child in getattr(node, field, []):
                    stack.append(child.body if isinstance(
                        child, ast.ExceptHandler) else child)
        if isinstance(node, ast.ExceptHandler):
            stack.extend(node.body)


def check_jax_free_module(rel: str, tree: ast.AST,
                          cfg: LintConfig) -> Iterator[tuple[str, int, str]]:
    if rel not in cfg.jax_free_modules:
        return
    for node in _module_scope_stmts(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == "jax":
                    yield ("jax-free-module", node.lineno,
                           f"{rel} must stay import-time jax-free "
                           "(lazy-import jax inside the function instead)")
        elif isinstance(node, ast.ImportFrom):
            if (node.module or "").split(".")[0] == "jax":
                yield ("jax-free-module", node.lineno,
                       f"{rel} must stay import-time jax-free "
                       "(lazy-import jax inside the function instead)")


def check_matrix_rank_hot_path(rel: str, tree: ast.AST,
                               cfg: LintConfig) -> Iterator[tuple[str, int, str]]:
    if not _under(rel, cfg.hot_path_dirs):
        return
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func) or (
            node.func.id if isinstance(node.func, ast.Name) else "")
        if name == "matrix_rank" or name.endswith(".matrix_rank"):
            yield ("matrix-rank-hot-path", node.lineno,
                   "matrix_rank call in a hot-path package: the per-event "
                   "decodability contract is core.decoder."
                   "IncrementalRankTracker (waive one-shot plan-construction "
                   "uses with a `repro: allow(matrix-rank-hot-path)` comment)")


def check_no_deprecated_surface(rel: str, tree: ast.AST,
                                cfg: LintConfig) -> Iterator[tuple[str, int, str]]:
    if rel == cfg.deprecated_module:
        return
    shim = cfg.deprecated_name
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.endswith(cfg.deprecated_module[:-3].replace("/", ".")):
                for alias in node.names:
                    if alias.name == shim:
                        yield ("no-deprecated-surface", node.lineno,
                               f"import of the deprecated {shim!r} shim: "
                               "internal callers must use repro.coded "
                               "(CodedMatmulConfig + plan/from_plan -> bind "
                               "-> apply)")
        elif isinstance(node, ast.Call):
            name = _dotted(node.func) or (
                node.func.id if isinstance(node.func, ast.Name) else "")
            if name == shim or name.endswith("." + shim):
                yield ("no-deprecated-surface", node.lineno,
                       f"call of the deprecated {shim!r} shim: internal "
                       "callers must use repro.coded")


RULES: tuple[Callable, ...] = (
    check_compat_boundary,
    check_jax_free_module,
    check_matrix_rank_hot_path,
    check_no_deprecated_surface,
)

RULE_NAMES = ("compat-boundary", "jax-free-module", "matrix-rank-hot-path",
              "no-deprecated-surface")


# -------------------------------- the engine --------------------------------

def _waivers(source: str) -> dict[int, set[str]]:
    """Physical source line -> rule names waived there."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        rules = set(WAIVER_RE.findall(text))
        if rules:
            out[i] = rules
    return out


def lint_source(rel: str, source: str,
                config: LintConfig | None = None) -> list[Finding]:
    """Run every contract rule over one file's source; apply waivers.

    A finding at line F is waived by ``# repro: allow(<rule>)`` written
    either trailing on line F or on the line directly above it.
    """
    config = config or LintConfig()
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as exc:
        return [Finding(rule="syntax", severity=ERROR, path=rel,
                        line=exc.lineno or 0, layer="lint",
                        message=f"cannot parse: {exc.msg}")]
    waivers = _waivers(source)
    used: set[tuple[int, str]] = set()
    findings = []
    for checker in RULES:
        for rule, line, message in checker(rel, tree, config):
            covering = [ln for ln in (line, line - 1)
                        if rule in waivers.get(ln, set())]
            if covering:
                used.add((covering[0], rule))
                continue
            findings.append(Finding(rule=rule, severity=ERROR, path=rel,
                                    line=line, message=message, layer="lint"))
    for line, rules in waivers.items():
        for rule in sorted(rules - {r for ln, r in used if ln == line}):
            findings.append(Finding(
                rule="unused-waiver", severity=ERROR, path=rel, line=line,
                layer="lint",
                message=f"waiver `repro: allow({rule})` suppresses "
                        "nothing; delete it"))
    return findings


def iter_source_files(root: Path) -> Iterator[Path]:
    for path in sorted(root.rglob("*.py")):
        if "__pycache__" in path.parts:
            continue
        yield path


def run_lint(root: Path | str | None = None,
             config: LintConfig | None = None) -> tuple[list[Finding], int]:
    """Lint every ``.py`` under ``root`` (default: the installed ``repro``
    package tree).  Returns (findings, files_checked)."""
    if root is None:
        import repro

        root = Path(repro.__file__).resolve().parent
    root = Path(root)
    findings: list[Finding] = []
    count = 0
    for path in iter_source_files(root):
        rel = path.relative_to(root).as_posix()
        findings.extend(lint_source(rel, path.read_text(), config))
        count += 1
    return findings, count
