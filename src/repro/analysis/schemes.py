"""Static validator for registered code schemes and their device plans.

The paper's guarantees are properties of the generator matrix, so every one
of them is checked here *without executing a multiply*:

* **recovery threshold** (Theorems 1-2): over seeded random arrival orders,
  the number of workers needed before the collected rows decode must stay
  within the scheme's declared bound (``SchemeInvariants``: exact for MDS
  designs, optimum + bounded overhead for the sparse/LT families);
* **degree / weight sanity**: no empty or over-full generator rows, finite
  nonzero stored weights, sparse designs keep O(log mn) mean row weight,
  and per-worker cost factors match the row structure;
* **chunk-expand exactness** (the chunked-protocol refinement): for every
  scheme and chunk count, the chunk rows of each parent row have disjoint
  supports and sum back to the parent EXACTLY -- the identity that makes a
  completed chunk a usable equation;
* **decode conditioning under worst-case survivor prefixes**: the decode
  matrix is applied in f32 on device, so the condition number of the
  surviving coefficient rows -- for minimal survivor subsets and for
  partial chunk prefixes -- must stay within float budget, and
  ``plan.decode`` must be a genuine left inverse;
* **BlockELL / tile-pack consistency**: packed tile indices stay in range,
  padding slots carry zero weight AND zero values, ``slot_of`` maps every
  live tile back to a live task slot, and the ELL round-trips to the dense
  operand bit-for-bit.

Everything here is generator-matrix math (numpy); plan- and pack-level
checks lazily import the device-path modules but never stage or run device
code.
"""

from __future__ import annotations

import dataclasses
import inspect
import zlib
from pathlib import Path

import numpy as np

from repro.analysis.findings import ERROR, WARNING, Finding
from repro.core.encoder import chunk_expand
from repro.core.schemes import SchemeInvariants

#: the (m, n, N) sweep every registered scheme is validated over
DEFAULT_CONFIGS: tuple[tuple[int, int, int], ...] = (
    (2, 2, 8),
    (2, 3, 12),
    (3, 3, 18),
)
DEFAULT_CHUNKS: tuple[int, ...] = (1, 2, 3)

#: additive slack on top of the fractional overhead bounds: tiny codes are
#: granular (one worker can be a whole +25% at mn=4), so a pure fraction
#: would be noise-driven
THRESHOLD_SLACK_WORKERS = 2

COND_ERROR = 1e12   # decode is numerically meaningless at any precision

#: instance seeds sampled for probabilistic (non-exact) designs -- LT-style
#: peeling decode is ALLOWED to fail for an unlucky sample, so decodability
#: is judged across seeds, not on one draw
SEED_SAMPLES = (0, 1, 2, 3, 4)

#: fallback profile for custom-registered schemes that declared nothing
PERMISSIVE = SchemeInvariants(mean_overhead=2.0, max_overhead=4.0,
                              dense_rows=True)


def _builder_anchor(scheme) -> tuple[str, int]:
    """file:line of the scheme's registered builder -- the code a scheme
    finding should point the author at."""
    try:
        src = inspect.getsourcefile(scheme.builder)
        _, line = inspect.getsourcelines(scheme.builder)
        import repro

        pkg = Path(repro.__file__).resolve().parent
        path = Path(src).resolve()
        rel = (path.relative_to(pkg).as_posix()
               if pkg in path.parents else str(path))
        return rel, line
    except (OSError, TypeError):  # pragma: no cover - builtins/partials
        return "coded/registry.py", 0


@dataclasses.dataclass
class _Ctx:
    """One scheme under validation: shared anchors and finding sink."""

    name: str
    scheme: object
    inv: SchemeInvariants
    findings: list[Finding]
    path: str = ""
    line: int = 0

    def __post_init__(self):
        self.path, self.line = _builder_anchor(self.scheme)

    def add(self, rule: str, message: str, severity: str = ERROR) -> None:
        self.findings.append(Finding(
            rule=rule, severity=severity, path=self.path, line=self.line,
            message=f"scheme {self.name!r}: {message}", layer="schemes"))


# ----------------------------- threshold check ------------------------------

def _measure_thresholds(inst, optimal: int, trials: int,
                        rng: np.random.Generator) -> np.ndarray | None:
    """Workers needed until decodable, over random arrival orders.
    None when even the full worker set cannot decode."""
    N = inst.num_workers
    if not inst.can_decode(list(range(N))):
        return None
    out = np.empty(trials, dtype=np.int64)
    for t in range(trials):
        order = rng.permutation(N).tolist()
        lo = optimal
        got = N
        for k in range(lo, N + 1):
            if inst.can_decode(order[:k]):
                got = k
                break
        out[t] = got
    return out


def check_recovery_threshold(ctx: _Ctx, make_inst, inst, m: int, n: int,
                             trials: int, rng: np.random.Generator) -> None:
    """Empirical recovery threshold vs the declared bound.

    Exact designs are judged on the seed-0 instance: ANY optimal-size subset
    must decode, deterministically.  Probabilistic designs (LT-style peeling
    in particular) are judged across ``SEED_SAMPLES`` instance draws --
    one undecodable sample is within the design's failure probability, a
    majority is a broken code.
    """
    inv = ctx.inv
    optimal = inv.optimal(m, n, inst.num_workers)
    tag = f"(m={m}, n={n}, N={inst.num_workers})"
    if inv.exact:
        thresholds = _measure_thresholds(inst, optimal, trials, rng)
        if thresholds is None:
            ctx.add("recovery-threshold",
                    f"{tag} not decodable even from ALL workers")
        elif int(thresholds.max()) != optimal:
            ctx.add("recovery-threshold",
                    f"{tag} declared exact (any {optimal} workers decode) "
                    f"but a sampled arrival order needed "
                    f"{int(thresholds.max())}")
        return
    per_seed = max(4, trials // len(SEED_SAMPLES))
    samples, fails = [], 0
    for seed in SEED_SAMPLES:
        th = _measure_thresholds(inst if seed == 0 else make_inst(seed),
                                 optimal, per_seed, rng)
        if th is None:
            fails += 1
        else:
            samples.append(th)
    if fails * 2 > len(SEED_SAMPLES):
        ctx.add("recovery-threshold",
                f"{tag} {fails}/{len(SEED_SAMPLES)} sampled instances are "
                "not decodable even from ALL workers: failure probability "
                "far above the design's")
        return
    if not samples:
        return
    thresholds = np.concatenate(samples)
    mean_cap = optimal + inv.mean_overhead * optimal + THRESHOLD_SLACK_WORKERS
    max_cap = optimal + inv.max_overhead * optimal + THRESHOLD_SLACK_WORKERS
    if thresholds.mean() > mean_cap:
        ctx.add("recovery-threshold",
                f"{tag} mean recovery threshold {thresholds.mean():.2f} "
                f"workers exceeds the declared bound {mean_cap:.2f} "
                f"(optimum {optimal} + {inv.mean_overhead:.0%} overhead)")
    if thresholds.max() > max_cap:
        ctx.add("recovery-threshold",
                f"{tag} worst sampled threshold {int(thresholds.max())} "
                f"workers exceeds the declared bound {max_cap:.2f}")


# --------------------------- degree / weight sanity -------------------------

def check_degree_weights(ctx: _Ctx, inst, m: int, n: int) -> None:
    d = m * n
    M = inst.M.tocsr()
    degrees = np.diff(M.indptr)
    tag = f"(m={m}, n={n}, N={inst.num_workers})"
    if (degrees == 0).any():
        ctx.add("degree-sanity",
                f"{tag} generator rows {np.flatnonzero(degrees == 0).tolist()} "
                "are empty: a worker with no task is pure overhead")
    if (degrees > d).any():
        ctx.add("degree-sanity",
                f"{tag} generator row degree exceeds mn={d} "
                "(duplicate column indices in a row?)")
    if M.nnz and (~np.isfinite(M.data)).any():
        ctx.add("weight-sanity", f"{tag} non-finite generator weights")
    if M.nnz and (M.data == 0.0).any():
        ctx.add("weight-sanity",
                f"{tag} explicitly stored zero weights: dead slots inflate "
                "every worker's cost factor")
    if not ctx.inv.dense_rows and degrees.size:
        cap = 3.0 * np.log(max(d, 2)) + 3.0
        if degrees.mean() > cap:
            ctx.add("degree-sanity",
                    f"{tag} mean row degree {degrees.mean():.2f} exceeds the "
                    f"sparse-design cap {cap:.2f} (~O(log mn), Theorem 1's "
                    "per-worker cost)")
    cf = np.asarray(inst.cost_factor, dtype=np.float64)
    if cf.shape[0] != inst.num_workers:
        ctx.add("cost-sanity",
                f"{tag} cost_factor has {cf.shape[0]} entries for "
                f"{inst.num_workers} workers")
    elif (~np.isfinite(cf)).any() or (cf <= 0).any():
        ctx.add("cost-sanity", f"{tag} cost factors must be finite and "
                               "positive")
    elif not ctx.inv.dense_rows and all(
            len(rows) == 1 for rows in inst.worker_rows):
        per_worker_deg = np.asarray(
            [degrees[rows[0]] for rows in inst.worker_rows], dtype=np.float64)
        if not np.allclose(cf, per_worker_deg):
            ctx.add("cost-sanity",
                    f"{tag} sum-of-products cost factors must equal row "
                    "degrees (paper Table I)")


# --------------------------- chunk-expand exactness -------------------------

def check_chunk_exactness(ctx: _Ctx, inst, m: int, n: int,
                          chunks: tuple[int, ...]) -> None:
    M = inst.M.tocsr()
    dense = M.toarray()
    tag = f"(m={m}, n={n}, N={inst.num_workers})"
    for q in chunks:
        E = chunk_expand(M, q)
        if E.shape != (M.shape[0] * q, M.shape[1]):
            ctx.add("chunk-exactness",
                    f"{tag} chunk_expand(q={q}) shape {E.shape} != "
                    f"{(M.shape[0] * q, M.shape[1])}")
            continue
        Ed = E.toarray()
        for r in range(M.shape[0]):
            group = Ed[r * q:(r + 1) * q]
            if not np.array_equal(group.sum(axis=0), dense[r]):
                ctx.add("chunk-exactness",
                        f"{tag} q={q}: chunk rows of generator row {r} do "
                        "not sum back to the parent row exactly")
                break
            support = (group != 0).sum(axis=0)
            if (support > 1).any():
                ctx.add("chunk-exactness",
                        f"{tag} q={q}: chunk rows of generator row {r} have "
                        "overlapping supports (a slot computed twice)")
                break


# ------------------- plan: decode exactness + conditioning ------------------

def _cond(M_rows: np.ndarray) -> float:
    sv = np.linalg.svd(M_rows, compute_uv=False)
    if sv.size == 0 or sv[-1] <= 0:
        return np.inf
    return float(sv[0] / sv[-1])


def check_plan_decode(ctx: _Ctx, plan, m: int, n: int, trials: int,
                      rng: np.random.Generator) -> None:
    """Left-inverse exactness plus conditioning of the worst-case survivor
    subsets and chunk prefixes the runtime may hand to ``with_survivors``."""
    from repro.core.decoder import DecodingError

    d = m * n
    N = plan.num_workers
    M = plan.coefficient_matrix()
    tag = f"(m={m}, n={n}, N={N})"
    resid = float(np.abs(plan.decode.astype(np.float64) @ M - np.eye(d)).max())
    if resid > 1e-3:
        ctx.add("decode-exactness",
                f"{tag} plan.decode is not a left inverse of the coefficient "
                f"matrix (max residual {resid:.2e})")

    worst = _cond(M)
    optimal = d  # one row per device on the SPMD path
    for _ in range(trials):
        surv = np.zeros(N, dtype=bool)
        surv[rng.choice(N, size=min(N, optimal + 1), replace=False)] = True
        M_surv = M[surv]
        if np.linalg.matrix_rank(M_surv) < d:
            continue  # not a decodable subset; with_survivors would refuse it
        worst = max(worst, _cond(M_surv))
    # partial chunk prefixes: the chunked protocol's worst case is a decode
    # from barely-enough completed chunks
    q = 2
    for _ in range(trials):
        progress = np.full(N, q)
        idx = rng.choice(N, size=min(N, 2), replace=False)
        progress[idx] = rng.integers(0, q, size=idx.size)
        try:
            masked = plan.with_chunk_progress(progress, q)
        except (DecodingError, ValueError):
            continue
        worst = max(worst, _cond(masked.coefficient_matrix()))
    if not np.isfinite(worst) or worst > COND_ERROR:
        ctx.add("decode-conditioning",
                f"{tag} worst-case survivor conditioning {worst:.2e} exceeds "
                f"{COND_ERROR:.0e}: the f32 device decode cannot represent "
                "this inverse")
    elif worst > ctx.inv.cond_warn:
        ctx.add("decode-conditioning",
                f"{tag} worst-case survivor conditioning {worst:.2e} exceeds "
                f"the scheme's declared budget {ctx.inv.cond_warn:.0e}: f32 "
                "decode accuracy is marginal", severity=WARNING)


# ----------------------- BlockELL / tile-pack consistency -------------------

def check_pack_consistency(ctx: _Ctx, plan, m: int, n: int,
                           rng: np.random.Generator) -> None:
    """Pack a deterministic sparse operand under this plan and verify every
    index-range/shape/padding contract of BlockELL and WorkerTilePack."""
    from repro.core.coded_matmul import pack_worker_tiles
    from repro.sparse.blocksparse import block_ell_to_dense, dense_to_block_ell

    bs = 4
    s, br = 16, 8
    r = m * br
    A = rng.standard_normal((s, r)).astype(np.float32)
    tile_mask = rng.random((s // bs, r // bs)) < 0.5
    A *= np.kron(tile_mask, np.ones((bs, bs), np.float32))
    tag = f"(m={m}, n={n}, N={plan.num_workers})"

    ell = dense_to_block_ell(A, block_size=bs)
    RB = s // bs
    if int(ell.idx.max(initial=0)) >= RB or int(ell.idx.min(initial=0)) < 0:
        ctx.add("pack-consistency",
                f"{tag} BlockELL row-block indices out of [0, {RB})")
    if (ell.nnzb > ell.slots).any():
        ctx.add("pack-consistency",
                f"{tag} BlockELL nnzb exceeds the slot count")
    if not np.array_equal(block_ell_to_dense(ell), A):
        ctx.add("pack-consistency",
                f"{tag} BlockELL does not round-trip the dense operand")

    pack = pack_worker_tiles(ell, plan)
    N, L = plan.cols.shape
    CBl = br // bs
    if pack.vals.shape[:3] != pack.src.shape[:3] or \
            pack.vals.shape[:3] != pack.wslot.shape:
        ctx.add("pack-consistency",
                f"{tag} pack vals/src/wslot leading shapes disagree: "
                f"{pack.vals.shape} vs {pack.src.shape} vs {pack.wslot.shape}")
        return
    if pack.vals.shape[0] != N or pack.vals.shape[1] != CBl:
        ctx.add("pack-consistency",
                f"{tag} pack is laid out for {pack.vals.shape[0]} workers x "
                f"{pack.vals.shape[1]} column blocks, plan needs {N} x {CBl}")
        return
    live = pack.wslot != 0.0
    if int(pack.src[..., 0].max(initial=0)) >= RB:
        ctx.add("pack-consistency",
                f"{tag} pack row-block addresses exceed s/bs={RB}: the "
                "fused gather would read out of range (XLA clamps silently)")
    if int(pack.src[..., 1].max(initial=0)) >= n:
        ctx.add("pack-consistency",
                f"{tag} pack column-group addresses exceed n={n}")
    if np.abs(np.where(live[..., None, None], 0.0, pack.vals)).max() != 0.0:
        ctx.add("pack-consistency",
                f"{tag} padding slots (zero weight) carry nonzero tile "
                "values: pads must contribute exactly nothing")
    if not np.array_equal(pack.live_tiles, live.sum(axis=(1, 2))):
        ctx.add("pack-consistency",
                f"{tag} live_tiles does not count the nonzero-weight slots")
    if pack.slot_of is None:
        ctx.add("pack-consistency",
                f"{tag} pack has no slot_of map: chunk-masked plans cannot "
                "re-gather weights (block_sparse would refuse this pack)")
    else:
        if int(pack.slot_of.max(initial=0)) >= L:
            ctx.add("pack-consistency",
                    f"{tag} slot_of exceeds the task table width {L}")
        k_idx = np.arange(N)[:, None, None]
        regathered = plan.weights[k_idx, pack.slot_of]
        if not np.array_equal(np.where(live, regathered, 0.0), pack.wslot):
            ctx.add("pack-consistency",
                    f"{tag} re-gathering weights through slot_of does not "
                    "reproduce wslot: chunk rebinds would compute with "
                    "wrong weights")


# --------------------------------- driver -----------------------------------

def validate_scheme(name: str, *,
                    configs=DEFAULT_CONFIGS, chunks=DEFAULT_CHUNKS,
                    trials: int = 20) -> list[Finding]:
    """Every static check for one registered scheme, across the sweep."""
    from repro.coded.registry import get_scheme

    scheme = get_scheme(name)
    ctx = _Ctx(name=name, scheme=scheme,
               inv=scheme.invariants or PERMISSIVE, findings=[])
    for m, n, N in configs:
        # crc32, not hash(): str hashing is salted per process and findings
        # must be reproducible run to run
        rng = np.random.default_rng(zlib.crc32(f"{name}:{m}:{n}:{N}".encode()))

        def make_inst(seed):
            return scheme.instance(m, n, None if scheme.fixed_workers else N,
                                   seed=seed)

        inst = make_inst(0)
        check_recovery_threshold(ctx, make_inst, inst, m, n, trials, rng)
        check_degree_weights(ctx, inst, m, n)
        check_chunk_exactness(ctx, inst, m, n, chunks)
        try:
            plan = scheme.plan(m, n, None if scheme.fixed_workers else N,
                               seed=0)
        except ValueError:
            continue  # no one-row-per-device SPMD plan (e.g. mds): host-only
        except RuntimeError as exc:
            ctx.add("plan-construction",
                    f"(m={m}, n={n}, N={N}) device plan construction failed: "
                    f"{exc}")
            continue
        check_plan_decode(ctx, plan, m, n, trials, rng)
        check_pack_consistency(ctx, plan, m, n, rng)
    return ctx.findings


def run_scheme_checks(*, configs=DEFAULT_CONFIGS, chunks=DEFAULT_CHUNKS,
                      trials: int = 20) -> tuple[list[Finding], int]:
    """Validate every scheme in the registry.  Returns
    (findings, schemes_checked)."""
    from repro.coded.registry import scheme_names

    findings: list[Finding] = []
    count = 0
    for name in scheme_names():
        findings.extend(validate_scheme(
            name, configs=configs, chunks=chunks, trials=trials))
        count += 1
    return findings, count
