"""Static invariant checking for the coded-matmul stack.

Three layers, one report, one CLI (``python -m repro.analysis``):

* ``repro.analysis.schemes``     -- generator-matrix math: recovery
  thresholds vs the paper's bounds, degree/weight sanity, chunk-expand
  exactness, decode conditioning, tile-pack consistency;
* ``repro.analysis.jaxpr_check`` -- staged-jaxpr verification: no dense
  materialization, collective axis names, dtype policy, per-equation
  memory accounting;
* ``repro.analysis.lint``        -- AST repo contracts: compat boundary,
  jax-free modules, hot-path rank calls, deprecated surfaces.

This package root is import-time jax-free (the jaxpr layer lazy-imports
jax) so the CLI can configure XLA_FLAGS before anything touches XLA.
"""

from repro.analysis.findings import ERROR, WARNING, Finding, Report

__all__ = ["ERROR", "WARNING", "Finding", "Report"]
