"""Reusable verification passes over staged (traced) coded matmuls.

The paper's structural guarantees survive staging as *shape and dtype facts
about the jaxpr*, so they can be proven on the trace without executing a
single multiply:

* ``stacked_intermediates`` -- the nnz-proportional claim (Theorem 1): the
  block_sparse program must never materialize an array with a
  ``max_degree * s`` leading dimension (the legacy stacked ``B_tall``
  gather).  This is THE detector: ``tests/spmd_coded_matmul_check.py`` and
  the ``repro.analysis`` CLI both call this one implementation, and
  ``assert_detector_sensitivity`` proves it still trips on the legacy
  construction it was built to catch.
* ``collective_axis_offenders`` -- every psum / reduce-scatter in the staged
  program names exactly the configured worker axis (a wrong or missing axis
  name decodes garbage silently under ``check_vma=False``).
* ``float64_offenders`` -- the dtype policy: no intermediate may be f64
  (silent promotion doubles HBM traffic and desyncs the f32 decode matrix).
* ``peak_equation_bytes`` -- per-equation operand+output byte accounting;
  the driver asserts the block_sparse path's peak stays within an
  nnz-proportional budget derived from the operands and the tile pack.

Every pass returns plain offender records; callers (tests, the CLI driver
``run_jaxpr_checks``) decide between asserting and emitting findings.
"""

from __future__ import annotations

import inspect
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import ERROR, WARNING, Finding

#: collectives whose axis names the staged program must get right (psum2 is
#: the spelling shard_map emits when tracing over an AbstractMesh)
_COLLECTIVE_PRIMS = ("psum", "psum2", "reduce_scatter", "psum_scatter",
                     "all_gather", "all_to_all", "ppermute")


def _sub_jaxprs(val) -> Iterator:
    from jax.core import ClosedJaxpr, Jaxpr

    if isinstance(val, ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _sub_jaxprs(v)


def iter_eqns(jaxpr) -> Iterator:
    """Every equation of ``jaxpr``, descending into sub-jaxprs (shard_map
    bodies, scan bodies, cond branches, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from iter_eqns(sub)


def walk_avals(jaxpr) -> Iterator[tuple[str, object]]:
    """(primitive name, output aval) of every equation, recursively."""
    for eqn in iter_eqns(jaxpr):
        for v in eqn.outvars:
            yield eqn.primitive.name, v.aval


def _closed(j):
    return j.jaxpr if hasattr(j, "jaxpr") else j


# ------------------------- pass: no dense materialization --------------------

def stacked_intermediates(jaxpr, stacked_rows: int) -> list[tuple[str, tuple]]:
    """Offending (primitive, shape) pairs whose output aval has a leading
    dimension of exactly ``stacked_rows`` = ``max_degree * s`` -- the row
    count of the legacy stacked-operand (``B_tall``) copy the fused-gather
    path exists to avoid."""
    return [
        (prim, tuple(aval.shape))
        for prim, aval in walk_avals(_closed(jaxpr))
        if getattr(aval, "shape", ()) and aval.shape[0] == stacked_rows
    ]


def legacy_stacked_gather(B, max_degree: int, s: int, n: int, bt: int):
    """The OLD B_tall construction (gather + transpose + reshape into a
    (max_degree * s, bt) stack) -- kept as the detector's sensitivity probe,
    never as an execution path."""
    bsel = jnp.take(B.reshape(s, n, bt),
                    jnp.zeros((max_degree,), jnp.int32), axis=1)
    return bsel.transpose(1, 0, 2).reshape(max_degree * s, bt)


def assert_detector_sensitivity(max_degree: int, s: int, n: int, bt: int,
                                dtype=jnp.float32) -> None:
    """Prove ``stacked_intermediates`` still flags the legacy construction.

    A detector that silently went blind (e.g. after a jaxpr representation
    change upstream) would let the dense path regress unnoticed; both the
    CLI and the SPMD check run this self-test alongside the real pass.
    """
    B = jax.ShapeDtypeStruct((s, n * bt), dtype)
    closed = jax.make_jaxpr(
        lambda b: legacy_stacked_gather(b, max_degree, s, n, bt))(B)
    tripped = stacked_intermediates(closed, max_degree * s)
    if not tripped:
        raise AssertionError(
            "jaxpr walker failed to flag the legacy stacked gather "
            f"(max_degree={max_degree}, s={s}): the no-dense-materialization "
            "detector has lost sensitivity")


# ----------------------- pass: one-launch decode epilogue --------------------

def _iter_eqns_outside_kernels(jaxpr) -> Iterator:
    """Like ``iter_eqns`` but does NOT descend into pallas_call bodies: the
    decode combine living inside a kernel is exactly the fused epilogue the
    one-launch contract wants, never an offender."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for param in eqn.params.values():
            for sub in _sub_jaxprs(param):
                yield from _iter_eqns_outside_kernels(sub)


#: primitives a staged-out-of-kernel decode combine can appear as: the
#: broadcast multiply of the legacy epilogue or an explicit D @ C~ contraction
_DECODE_PRIMS = ("mul", "dot_general", "broadcast_in_dim")


def decode_contraction_offenders(jaxpr, mn: int, br: int) -> list[tuple[str, tuple]]:
    """Equations OUTSIDE any kernel that build the decode-weighted stack: a
    mul / dot_general / broadcast with a rank-3 ``(mn, br, *)`` output.  On
    the one-launch path that stack may only be born inside the fused
    kernel's epilogue, so any hit means a separate decode launch (and an
    HBM round-trip of C~) regressed into the staged program.  ``mn == 1``
    is skipped: a single-block decode is shape-indistinguishable from the
    local product itself."""
    if mn <= 1:
        return []
    return [
        (eqn.primitive.name, tuple(v.aval.shape))
        for eqn in _iter_eqns_outside_kernels(_closed(jaxpr))
        if eqn.primitive.name in _DECODE_PRIMS
        for v in eqn.outvars
        if getattr(v.aval, "shape", None) is not None
        and len(v.aval.shape) == 3
        and v.aval.shape[0] == mn and v.aval.shape[1] == br
    ]


def fused_epilogue_launches(jaxpr, mn: int) -> list[tuple]:
    """Output shapes of every pallas_call that emits the decode-fused stack
    (rank-3, leading dim mn).  Empty means the program never ran the
    one-launch kernel -- the epilogue contract is vacuous without it."""
    out = []
    for eqn in iter_eqns(_closed(jaxpr)):
        if eqn.primitive.name != "pallas_call":
            continue
        for v in eqn.outvars:
            shape = getattr(v.aval, "shape", None)
            if shape is not None and len(shape) == 3 and shape[0] == mn:
                out.append(tuple(shape))
    return out


def legacy_decode_combine(dvec, Ct):
    """The OLD two-step epilogue (broadcast multiply of the decode column
    against the local product) -- the decode detector's sensitivity probe,
    never an execution path."""
    return dvec[:, None, None] * Ct[None]


def assert_decode_detector_sensitivity(mn: int, br: int, bt: int,
                                       dtype=jnp.float32) -> None:
    """Prove ``decode_contraction_offenders`` still flags the legacy
    two-step combine (same blind-detector rationale as the stacked-gather
    self-test)."""
    dvec = jax.ShapeDtypeStruct((mn,), dtype)
    Ct = jax.ShapeDtypeStruct((br, bt), dtype)
    closed = jax.make_jaxpr(legacy_decode_combine)(dvec, Ct)
    if not decode_contraction_offenders(closed, mn, br):
        raise AssertionError(
            "jaxpr walker failed to flag the legacy decode combine "
            f"(mn={mn}, br={br}, bt={bt}): the one-launch-epilogue detector "
            "has lost sensitivity")


def verify_fused_epilogue(closed, *, mn: int, br: int, context: str) -> list[Finding]:
    """The one-launch contract for a kernel-lane staged fused program: the
    decode stack is born inside a pallas_call epilogue and nowhere else."""
    path, line = _staging_anchor()

    def finding(message):
        return Finding(rule="one-launch-epilogue", severity=ERROR, path=path,
                       line=line, message=f"{context}: {message}",
                       layer="jaxpr")

    out = []
    offenders = decode_contraction_offenders(closed, mn, br)
    if offenders:
        out.append(finding(
            f"separate decode contraction staged outside the kernel: "
            f"{offenders[:3]} -- the decode combine must ride the fused "
            "epilogue"))
    if mn > 1 and not fused_epilogue_launches(closed, mn):
        out.append(finding(
            "no pallas_call emits the (mn, br, bt) decode-fused stack: the "
            "one-launch kernel never ran"))
    return out


# --------------------------- pass: collective axes ---------------------------

def _eqn_axis_names(eqn) -> tuple:
    axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    return tuple(a for a in axes if isinstance(a, str))


def collective_axis_offenders(jaxpr, axis_name: str) -> list[tuple[str, tuple]]:
    """Collectives whose named axes are not exactly ``(axis_name,)``."""
    out = []
    for eqn in iter_eqns(_closed(jaxpr)):
        if eqn.primitive.name not in _COLLECTIVE_PRIMS:
            continue
        names = _eqn_axis_names(eqn)
        if names != (axis_name,):
            out.append((eqn.primitive.name, names))
    return out


def collective_prims(jaxpr) -> list[str]:
    """Names of every collective equation in the program (the decode psum /
    reduce-scatter must exist at all -- zero collectives means the program
    never combined worker contributions)."""
    return [eqn.primitive.name for eqn in iter_eqns(_closed(jaxpr))
            if eqn.primitive.name in _COLLECTIVE_PRIMS]


# ----------------------------- pass: dtype policy ----------------------------

def float64_offenders(jaxpr) -> list[tuple[str, tuple, str]]:
    """(primitive, shape, dtype) of every f64 intermediate.  The device path
    is an f32 pipeline end to end (decode matrices are staged as f32); an
    f64 aval means a silent promotion leaked into the staged computation."""
    out = []
    for prim, aval in walk_avals(_closed(jaxpr)):
        dt = getattr(aval, "dtype", None)
        if dt is not None and np.dtype(dt) == np.float64:
            out.append((prim, tuple(aval.shape), str(dt)))
    return out


# ------------------------- pass: peak-bytes accounting -----------------------

def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dt = getattr(aval, "dtype", None)
    if shape is None or dt is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize


def peak_equation_bytes(jaxpr) -> tuple[int, str, list[tuple]]:
    """Max over equations of (operand + output bytes); returns
    (bytes, primitive, shapes) of the peak equation.  This is the static
    proxy for peak live memory: an equation that touches a
    max_degree-times-blown-up operand shows up here even if XLA later fuses
    it away, which is exactly the conservatism a CI gate wants."""
    peak, peak_prim, peak_shapes = 0, "<empty>", []
    for eqn in iter_eqns(_closed(jaxpr)):
        total = sum(_aval_bytes(v.aval) for v in (*eqn.invars, *eqn.outvars)
                    if hasattr(v, "aval"))
        if total > peak:
            peak = total
            peak_prim = eqn.primitive.name
            peak_shapes = [tuple(getattr(v.aval, "shape", ()))
                           for v in (*eqn.invars, *eqn.outvars)
                           if hasattr(v, "aval")]
    return peak, peak_prim, peak_shapes


def nnz_proportional_budget(plan, pack, s: int, r: int, t: int,
                            slack: float = 2.0) -> int:
    """Byte budget for one staged block_sparse equation: the operands, the
    packed live tiles, the (padded) decode contribution, and the result --
    nothing in the program may touch more than ``slack`` times their sum.
    The legacy stacked ``B_tall`` copy (``max_degree * s`` rows) blows past
    this the moment max_degree exceeds n, which is the regression the
    accounting exists to catch."""
    N = plan.num_workers
    m, n = plan.m, plan.n
    br, bt = r // m, t // n
    mn_pad = -(-m * n // N) * N
    itemsize = 4  # the staged pipeline is f32 end to end (dtype pass enforces)
    terms = [
        s * r,                      # A (replicated operand)
        s * t,                      # B (replicated operand)
        int(np.prod(pack.vals.shape)) if pack is not None else 0,
        mn_pad * br * bt,           # per-device decode contribution
        m * br * n * bt,            # the assembled C
    ]
    return int(slack * itemsize * sum(terms))


# ------------------------------- CLI driver ----------------------------------

def _staging_anchor() -> tuple[str, int]:
    """file:line of ``stage_coded_matmul`` -- the one place every verified
    program is staged from, hence the natural anchor for jaxpr findings."""
    from repro.core import coded_matmul

    try:
        _, line = inspect.getsourcelines(coded_matmul.stage_coded_matmul)
    except OSError:  # pragma: no cover - source unavailable (zipapp etc.)
        line = 0
    return "core/coded_matmul.py", line


def verify_staged_program(closed, *, axis_name: str, stacked_rows: int | None,
                          byte_budget: int | None,
                          context: str) -> list[Finding]:
    """Run every applicable pass over one staged program; findings only."""
    path, line = _staging_anchor()

    def finding(rule, message, severity=ERROR):
        return Finding(rule=rule, severity=severity, path=path, line=line,
                       message=f"{context}: {message}", layer="jaxpr")

    out = []
    if stacked_rows is not None:
        offenders = stacked_intermediates(closed, stacked_rows)
        if offenders:
            out.append(finding(
                "no-dense-materialization",
                f"program materializes {stacked_rows}-row intermediates "
                f"(max_degree * s): {offenders[:3]}"))
    bad_axes = collective_axis_offenders(closed, axis_name)
    if bad_axes:
        out.append(finding(
            "collective-axis",
            f"collectives over unexpected axes (want {axis_name!r}): "
            f"{bad_axes}"))
    if not collective_prims(closed):
        out.append(finding(
            "collective-axis",
            "no collective in the staged program: worker contributions are "
            "never combined"))
    f64 = float64_offenders(closed)
    if f64:
        out.append(finding(
            "dtype-policy",
            f"float64 intermediates in the staged f32 pipeline: {f64[:3]}"))
    if byte_budget is not None:
        peak, prim, shapes = peak_equation_bytes(closed)
        if peak > byte_budget:
            out.append(finding(
                "memory-budget",
                f"peak equation touches {peak} bytes > nnz-proportional "
                f"budget {byte_budget} (primitive {prim}, shapes "
                f"{shapes[:4]})"))
    return out


def run_jaxpr_checks(max_schemes: int | None = None) -> tuple[list[Finding], int]:
    """Stage coded matmuls for every device-capable registered scheme across
    backends x decode layouts and verify each trace.  Returns
    (findings, programs_verified).  Tracing only -- nothing executes on
    device, but a mesh over the visible devices is required to stage."""
    from repro import compat
    from repro.coded import CodedMatmulConfig, from_plan, get_scheme, scheme_names
    from repro.core.coded_matmul import pack_worker_tiles
    from repro.sparse import dense_to_block_ell

    path, line = _staging_anchor()
    findings: list[Finding] = []
    programs = 0

    # detector self-tests first: a blind detector must fail the run, not
    # silently bless it
    try:
        assert_detector_sensitivity(max_degree=6, s=32, n=2, bt=12)
    except AssertionError as exc:
        findings.append(Finding(
            rule="no-dense-materialization", severity=ERROR, path=path,
            line=line, layer="jaxpr", message=str(exc)))
        return findings, programs
    try:
        assert_decode_detector_sensitivity(mn=4, br=8, bt=12)
    except AssertionError as exc:
        findings.append(Finding(
            rule="one-launch-epilogue", severity=ERROR, path=path,
            line=line, layer="jaxpr", message=str(exc)))
        return findings, programs

    devices = jax.devices()
    m = n = 2
    names = [nm for nm in scheme_names()]
    if max_schemes is not None:
        names = names[:max_schemes]
    rng = np.random.default_rng(0)
    s, r, t = 32, 8 * m, 12 * n
    br, bt = r // m, t // n
    A_np = rng.standard_normal((s, r)).astype(np.float32)
    mask = rng.random((s // 8, r // 8)) < 0.5
    A_np *= np.kron(mask, np.ones((8, 8), np.float32))
    B_np = rng.standard_normal((s, t)).astype(np.float32)
    ell = dense_to_block_ell(A_np, block_size=8)

    for name in names:
        sch = get_scheme(name)
        N = m * n if sch.fixed_workers else max(len(devices), m * n + 2)
        if N > len(devices):
            findings.append(Finding(
                rule="coverage", severity=WARNING, path=path, line=line,
                layer="jaxpr",
                message=f"scheme {name!r}: needs {N} devices, only "
                        f"{len(devices)} visible -- staging skipped (run via "
                        "the CLI, which forces an 8-device host platform)"))
            continue
        try:
            plan = sch.plan(m, n, None if sch.fixed_workers else N, seed=5)
        except ValueError:
            continue  # not device-capable (e.g. mds): nothing to stage
        mesh = compat.make_mesh((plan.num_workers,), ("model",),
                                devices=devices[:plan.num_workers])
        pack = pack_worker_tiles(ell, plan)
        budget = nnz_proportional_budget(plan, pack, s, r, t)
        A = jnp.asarray(A_np)
        B = jnp.asarray(B_np)
        for backend in ("dense_scan", "block_sparse"):
            for out_sharded in (False, True):
                cfg = CodedMatmulConfig(backend=backend,
                                        out_sharded=out_sharded)
                op = from_plan(cfg, plan).bind(mesh)
                kw = {"a_sparse": ell} if backend == "block_sparse" else {}
                closed = jax.make_jaxpr(
                    lambda a, b: op.apply(a, b, **kw))(A, B)
                # max_degree == 1 would make the stacked row count collide
                # with the operands' own (s, ...) shapes: nothing to detect
                findings.extend(verify_staged_program(
                    closed, axis_name="model",
                    stacked_rows=(plan.max_degree * s
                                  if backend == "block_sparse"
                                  and plan.max_degree > 1 else None),
                    byte_budget=(budget if backend == "block_sparse"
                                 else None),
                    context=(f"scheme={name} backend={backend} "
                             f"out_sharded={out_sharded}")))
                programs += 1
                if backend != "block_sparse" or out_sharded:
                    continue
                # one-launch contract: re-stage on the TPU kernel lane (the
                # pallas_call appears in the trace regardless of the host
                # platform; nothing executes) and prove the decode combine
                # lives in the kernel epilogue, not as a separate launch
                import os

                prev = os.environ.get("REPRO_KERNEL_LANE")
                os.environ["REPRO_KERNEL_LANE"] = "tpu"
                try:
                    closed_k = jax.make_jaxpr(
                        lambda a, b: op.apply(a, b, **kw))(A, B)
                finally:
                    if prev is None:
                        del os.environ["REPRO_KERNEL_LANE"]
                    else:
                        os.environ["REPRO_KERNEL_LANE"] = prev
                findings.extend(verify_fused_epilogue(
                    closed_k, mn=m * n, br=br,
                    context=(f"scheme={name} backend={backend} "
                             "lane=tpu")))
                programs += 1
    return findings, programs
