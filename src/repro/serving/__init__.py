"""repro.serving: multi-tenant coded serving (DESIGN.md section 11).

Scheduler and load generator are jax-free host logic and import eagerly;
the engine (and serve_step) pull in jax, so they load lazily -- importing
``repro.serving`` for scheduling/metrics never initializes a backend.
"""

from repro.serving.loadgen import ClosedLoopLoad, TenantSpec, poisson_trace
from repro.serving.scheduler import (SLO, ContinuousBatcher, Request,
                                     ServingMetrics, percentile)

__all__ = [
    "SLO", "Request", "ContinuousBatcher", "ServingMetrics", "percentile",
    "TenantSpec", "poisson_trace", "ClosedLoopLoad",
    "ServingEngine", "generate", "jitted_decode_step",
]

_LAZY = {
    "ServingEngine": ("repro.serving.engine", "ServingEngine"),
    "generate": ("repro.serving.serve_step", "generate"),
    "jitted_decode_step": ("repro.serving.serve_step", "jitted_decode_step"),
}


def __getattr__(name):
    try:
        mod, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), attr)
