"""Load generation: Poisson open-loop traces and closed-loop clients.

Jax-free (enforced by the ``repro.analysis`` jax-free-module rule) and
deterministic: a ``(seed, tenant mix)`` pair always yields the same trace,
so serving benchmarks are reproducible and tests can assert on exact
arrival sequences.

Two standard load models:

- ``poisson_trace``: open loop.  Each tenant submits with exponential
  inter-arrival times at its own rate, regardless of completions -- the
  model behind "p99 under load" numbers, since queueing delay compounds
  when the server falls behind.
- ``ClosedLoopLoad``: each of ``concurrency`` virtual clients keeps
  exactly one request outstanding; the caller feeds completions back via
  ``next_request``.  Measures capability (peak throughput), not tail
  behaviour under overload.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Optional

from repro.serving.scheduler import SLO, Request


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic class in the mix."""

    name: str
    rate: float                   # requests/second (open loop)
    prompt_len: int = 8
    max_new_tokens: int = 4
    slo: SLO = dataclasses.field(default_factory=SLO)
    weight: float = 1.0           # closed loop: share of clients


def poisson_trace(tenants: list[TenantSpec], *, horizon: float,
                  seed: int = 0, max_requests: Optional[int] = None,
                  ) -> list[Request]:
    """Open-loop Poisson arrivals per tenant, merged and sorted by time.

    Each tenant gets an independent exponential inter-arrival stream
    (rate ``t.rate``) from its own sub-seed, so adding a tenant to the mix
    never perturbs another tenant's arrivals.
    """
    if horizon <= 0:
        raise ValueError(f"horizon must be > 0, got {horizon}")
    reqs: list[Request] = []
    for ti, t in enumerate(tenants):
        if t.rate <= 0:
            raise ValueError(f"tenant {t.name!r}: rate must be > 0, got {t.rate}")
        # string seeds hash via sha512 (stable across processes); a tuple
        # seed would go through PYTHONHASHSEED-salted hashing and vary
        rng = random.Random(f"{seed}:{t.name}")
        now, k = 0.0, 0
        while True:
            now += rng.expovariate(t.rate)
            if now >= horizon:
                break
            reqs.append(Request(
                rid=f"{t.name}-{k}", tenant=t.name, arrival_time=now,
                prompt_len=t.prompt_len, max_new_tokens=t.max_new_tokens,
                slo=t.slo, prompt_seed=hash((seed, ti, k)) & 0x7FFFFFFF))
            k += 1
    reqs.sort(key=lambda r: (r.arrival_time, r.tenant, r.rid))
    if max_requests is not None:
        reqs = reqs[:max_requests]
    return reqs


class ClosedLoopLoad:
    """``concurrency`` virtual clients, one outstanding request each.

    ``initial()`` yields the first wave; each completion is exchanged for
    the tenant's next request via ``next_request`` until ``total`` have
    been issued.  Tenant assignment of clients follows ``weight``.
    """

    def __init__(self, tenants: list[TenantSpec], *, concurrency: int,
                 total: int, seed: int = 0):
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        self.tenants = {t.name: t for t in tenants}
        self.total = int(total)
        self._issued = 0
        self._rng = random.Random(seed)
        # deterministic largest-remainder split of clients over weights
        wsum = sum(t.weight for t in tenants)
        shares = [(t.name, concurrency * t.weight / wsum) for t in tenants]
        counts = {name: int(s) for name, s in shares}
        rem = sorted(shares, key=lambda p: -(p[1] - int(p[1])))
        for name, _ in rem[:concurrency - sum(counts.values())]:
            counts[name] += 1
        self._clients = [name for name, c in counts.items() for _ in range(c)]

    def _make(self, tenant: str, now: float) -> Request:
        t = self.tenants[tenant]
        k = self._issued
        self._issued += 1
        return Request(
            rid=f"{tenant}-cl{k}", tenant=tenant, arrival_time=now,
            prompt_len=t.prompt_len, max_new_tokens=t.max_new_tokens,
            slo=t.slo, prompt_seed=self._rng.randrange(1 << 31))

    def initial(self) -> list[Request]:
        return [self._make(name, 0.0)
                for name in self._clients if self._issued < self.total]

    def next_request(self, completed: Request, now: float) -> Optional[Request]:
        if self._issued >= self.total:
            return None
        return self._make(completed.tenant, now)
