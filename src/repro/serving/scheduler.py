"""Continuous-batching request scheduler + per-request SLO metrics.

Iteration-level (continuous) batching: the engine calls ``admit`` every
token step, so a finished request's slot is refilled immediately instead of
waiting for the whole batch to drain.  Admission is FIFO *within* a tenant
and round-robin *across* tenants -- one chatty tenant cannot starve the
queue position of another -- with a hard cap of ``max_batch`` requests in
flight.

This module is deliberately jax-free (enforced by the ``repro.analysis``
jax-free-module lint rule): scheduling decisions and metric accounting are
pure host logic, testable without an accelerator and reusable against the
simulated or the live executor.  Time is a float the caller supplies, so
the same scheduler runs under a virtual clock in tests and wall clock in
the engine.
"""

from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict, deque
from typing import Iterable, Optional


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency objectives, seconds.  ``inf`` = unconstrained."""

    ttft: float = math.inf        # time to first token
    per_token: float = math.inf   # mean time per output token (TPOT)


@dataclasses.dataclass
class Request:
    """One generation request plus its measured lifecycle."""

    rid: str
    tenant: str
    arrival_time: float
    prompt_len: int
    max_new_tokens: int
    slo: SLO = dataclasses.field(default_factory=SLO)
    prompt_seed: int = 0          # deterministic prompt synthesis

    # -- runtime state, owned by scheduler/engine --
    admitted_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_latencies: list = dataclasses.field(default_factory=list)
    tokens: list = dataclasses.field(default_factory=list)
    straggler_recoveries: int = 0
    error: Optional[str] = None

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> Optional[float]:
        """Mean time per output token over the decode phase."""
        if not self.token_latencies:
            return None
        return sum(self.token_latencies) / len(self.token_latencies)

    @property
    def completed(self) -> bool:
        return self.finish_time is not None and self.error is None

    def meets_slo(self) -> bool:
        if not self.completed:
            return False
        if self.ttft is not None and self.ttft > self.slo.ttft:
            return False
        tpot = self.tpot
        if tpot is not None and tpot > self.slo.per_token:
            return False
        return True


class ContinuousBatcher:
    """Admission queue with FIFO-within-tenant, round-robin-across-tenants.

    Invariants (test-enforced): ``len(running) <= max_batch`` always; a
    tenant's requests are admitted in submission order; when several
    tenants have waiting requests, consecutive admissions rotate over them.
    """

    def __init__(self, max_batch: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = int(max_batch)
        self._queues: "OrderedDict[str, deque[Request]]" = OrderedDict()
        self._rr = 0  # rotating tenant pointer, advances per admission
        self.running: list[Request] = []

    def submit(self, req: Request) -> None:
        self._queues.setdefault(req.tenant, deque()).append(req)

    @property
    def waiting(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def waiting_for(self, tenant: str) -> int:
        return len(self._queues.get(tenant, ()))

    def admit(self, now: float) -> list[Request]:
        """Fill free slots; returns the newly admitted requests in order."""
        admitted = []
        while len(self.running) < self.max_batch:
            tenants = [t for t, q in self._queues.items() if q]
            if not tenants:
                break
            tenant = tenants[self._rr % len(tenants)]
            self._rr += 1
            req = self._queues[tenant].popleft()
            req.admitted_time = now
            self.running.append(req)
            admitted.append(req)
        return admitted

    def retire(self, req: Request, now: float) -> None:
        req.finish_time = now
        self.running.remove(req)


def percentile(values: Iterable[float], p: float) -> float:
    """Linear-interpolation percentile (numpy semantics, stdlib-only)."""
    vals = sorted(values)
    if not vals:
        return math.nan
    if len(vals) == 1:
        return float(vals[0])
    rank = (p / 100.0) * (len(vals) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(vals) - 1)
    frac = rank - lo
    return float(vals[lo] * (1.0 - frac) + vals[hi] * frac)


class ServingMetrics:
    """Aggregates finished requests into the bench's serving schema."""

    def __init__(self):
        self.requests: list[Request] = []

    def record(self, req: Request) -> None:
        self.requests.append(req)

    def summary(self) -> dict:
        """The ``serving`` schema of ``BENCH_coded_matmul.json``: latencies
        in milliseconds, SLO attainment over ALL finished requests (a
        failed request is an SLO miss, not a dropped sample)."""
        completed = [r for r in self.requests if r.completed]
        failed = [r for r in self.requests if not r.completed]
        token_lat = [lat for r in completed for lat in r.token_latencies]
        ttfts = [r.ttft for r in completed if r.ttft is not None]
        n = len(self.requests)
        by_tenant: dict[str, int] = {}
        for r in self.requests:
            by_tenant[r.tenant] = by_tenant.get(r.tenant, 0) + 1
        return {
            "requests": n,
            "completed": len(completed),
            "failed": len(failed),
            "by_tenant": by_tenant,
            "tokens": sum(len(r.tokens) for r in completed),
            "ttft_p50_ms": percentile(ttfts, 50) * 1e3 if ttfts else None,
            "ttft_p95_ms": percentile(ttfts, 95) * 1e3 if ttfts else None,
            "token_p50_ms": percentile(token_lat, 50) * 1e3 if token_lat else None,
            "token_p95_ms": percentile(token_lat, 95) * 1e3 if token_lat else None,
            "token_p99_ms": percentile(token_lat, 99) * 1e3 if token_lat else None,
            "slo_attainment": (sum(r.meets_slo() for r in self.requests) / n
                               if n else None),
            "straggler_recoveries": sum(r.straggler_recoveries
                                        for r in self.requests),
        }
