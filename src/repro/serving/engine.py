"""Multi-tenant coded serving engine: continuous batching over one JobMux.

The tentpole data flow: every token step, each in-flight request's routed
expert-FFN product is submitted as one coded matmul job -- MANY concurrent
jobs, one per request, against ONE shared worker pool
(``runtime.executor.JobMux``) and one shared pack cache.  The jitted model
remains authoritative for logits (its in-graph MoE runs the same coded
encode/decode when ``opt_coded_moe`` is on, with the decode matrix injected
as a traced argument so survivor rebinds never retrace); the JobMux job is
the *distributed* execution of the same expert product, which (a) is
verified exact against the host-side uncoded product every token and
(b) supplies the latency/fault model: a token's latency is the jit step
plus the distributed job's completion time, so a slow or killed worker
shows up in the token tail exactly as it would in a disaggregated
deployment.

Coded vs uncoded arms differ ONLY in the code on the wire: the same pool
size, the same block split of the expert weight, the same jit trace.  The
uncoded code places one block per worker (no redundancy), so a dead worker
fails the request; the coded scheme decodes from any sufficient prefix and
records a straggler recovery instead.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schemes as schemes_lib
from repro.coded.registry import get_scheme
from repro.models import moe as moe_lib
from repro.models.registry import build
from repro.runtime.executor import JobMux, MuxJob
from repro.serving.scheduler import ContinuousBatcher, Request, ServingMetrics
from repro.serving.serve_step import make_decode_step


@dataclasses.dataclass
class _Live:
    """Per-request decode state while the request holds a batch slot."""

    cache: object
    tok: int
    rng: object
    pending_tok: int = -1


class ServingEngine:
    """Continuous-batching generation with coded expert-FFN offload.

    ``coded=True`` turns on ``opt_coded_moe`` in the model config (in-jit
    coded expert matmuls) AND uses the config's coded scheme for the
    distributed per-token jobs; ``coded=False`` keeps the plain model and
    submits uncoded jobs.  ``source``/``straggler_sleep``/``dead_workers``/
    ``straggler`` configure the shared pool exactly as ``JobMux`` does; a
    started source object (e.g. ``MuxProcPool``) may be passed directly.
    """

    def __init__(self, cfg, *, coded: bool = True, num_workers: int = 6,
                 source="sim", n_blocks: int = 4, num_chunks: int = 2,
                 straggler=None, straggler_sleep=None, dead_workers=(),
                 timeout: float = 60.0, max_batch: int = 4, seed: int = 0,
                 max_seq: int = 64, moe_survivors=None,
                 unit_block_time: float = 1.0):
        if cfg.moe is None:
            raise ValueError(f"{cfg.name}: ServingEngine needs a MoE config "
                             "(the coded jobs are expert-FFN products)")
        self.coded = bool(coded)
        if self.coded and not getattr(cfg, "opt_coded_moe", False):
            cfg = cfg.with_opts(["coded_moe"])
        self.cfg = cfg
        self.n_blocks = int(n_blocks)
        self.num_chunks = int(num_chunks)
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)

        self.model = build(cfg)
        self.params = self.model.init(jax.random.key(seed))

        # host-side mirrors (f64) for routing + exactness checks: group 0 of
        # the first MoE slot; params are stacked (num_groups, ...) per slot
        ffn = next(p["ffn"] for p in self.params["groups"].values()
                   if "w_gate" in p["ffn"])
        self._router = np.asarray(ffn["router"][0], dtype=np.float64)   # (d, E)
        self._w_gate = np.asarray(ffn["w_gate"][0], dtype=np.float64)   # (E, d, ff)
        self._embed = np.asarray(self.params["embed"], dtype=np.float64)

        # the code on the wire: same (m=1, n=n_blocks) block grid both arms
        if self.coded:
            self._code = get_scheme(cfg.coded.scheme).instance(
                1, self.n_blocks, num_workers, seed=seed)
        else:
            self._code = schemes_lib.uncoded(1, self.n_blocks)
        if self._code.num_workers > num_workers:
            raise ValueError(f"code wants {self._code.num_workers} workers, "
                             f"pool has {num_workers}")

        self.mux = JobMux(num_workers, source=source, straggler=straggler,
                          straggler_sleep=straggler_sleep,
                          dead_workers=dead_workers, timeout=timeout,
                          unit_block_time=unit_block_time) \
            if isinstance(source, str) else JobMux(num_workers, source=source)

        # in-jit decode matrix, passed as a traced argument (survivor rebind
        # without retrace); a dummy when the model path is uncoded
        if self.coded:
            D = moe_lib.coded_moe_decode_matrix(cfg, survivors=moe_survivors)
        else:
            D = np.zeros((1, 1), dtype=np.float32)
        self._D = jnp.asarray(D)

        model = self.model

        def _prefill_fn(params, tokens, D):
            with moe_lib.coded_moe_decode(D):
                return model.prefill(params, tokens, max_seq=self.max_seq,
                                     cache_dtype=jnp.float32)

        step = make_decode_step(model, 0.0)

        def _decode_fn(params, cache, tok, rng, D):
            with moe_lib.coded_moe_decode(D):
                return step(params, cache, tok, rng)

        self._prefill = jax.jit(_prefill_fn)   # retraces per prompt_len only
        self._decode = jax.jit(_decode_fn)     # one trace: (1, 1) always

    # ------------------------------ pieces -----------------------------------

    def _prompt(self, req: Request) -> jnp.ndarray:
        rng = np.random.default_rng(req.prompt_seed)
        toks = rng.integers(0, self.cfg.vocab_size, size=(1, req.prompt_len))
        return jnp.asarray(toks, dtype=jnp.int32)

    def _expert_job(self, req: Request, token: int):
        """The distributed job for ``token``'s expert product, plus the host
        operands for the exactness check."""
        x = self._embed[token]                       # (d,)
        e = int(np.argmax(x @ self._router))         # layer-0 routed expert
        W = self._w_gate[e]                          # (d, ff)
        job = MuxJob(code=self._code, A_blocks=[x[:, None]],
                     B_blocks=np.array_split(W, self.n_blocks, axis=1),
                     n=self.n_blocks, num_chunks=self.num_chunks, tag=req.rid)
        return job, x, W

    @staticmethod
    def _exact(blocks, x, W) -> bool:
        got = np.hstack([np.asarray(b).reshape(1, -1) for b in blocks])
        return bool(np.allclose(got, x[None, :] @ W, rtol=1e-6, atol=1e-8))

    def warmup(self, prompt_lens=(8,)) -> None:
        """Pay jit tracing/compile outside the measured serving loop: one
        throwaway prefill per prompt length plus one decode micro-step."""
        for plen in sorted(set(int(p) for p in prompt_lens)):
            toks = jnp.zeros((1, plen), dtype=jnp.int32)
            logits, cache = self._prefill(self.params, toks, self._D)
            int(jnp.argmax(logits[:, -1], axis=-1)[0])
            _, sub = jax.random.split(jax.random.key(0))
            _ = self._decode(self.params, cache,
                             jnp.zeros((1, 1), dtype=jnp.int32),
                             sub, self._D)
        # ... and the pool's cold paths (chunk expansion, decode planning):
        # one throwaway expert job through the shared mux
        self.mux.start()
        warm = Request(rid="__warmup__", tenant="__warmup__",
                       arrival_time=0.0, prompt_len=1, max_new_tokens=1)
        job, _, _ = self._expert_job(warm, 0)
        self.mux.run([job])

    # ------------------------------ the loop ---------------------------------

    def run(self, requests: list[Request], *,
            metrics: ServingMetrics | None = None) -> ServingMetrics:
        """Serve an (open-loop) trace of requests to completion.

        Wall clock replays ``arrival_time``s; every iteration admits into
        free slots, prefills newcomers, runs ONE decode micro-step per
        running request, and dispatches the whole step's expert jobs as one
        concurrent JobMux batch.
        """
        self.mux.start()
        metrics = metrics if metrics is not None else ServingMetrics()
        pending = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
        batcher = ContinuousBatcher(self.max_batch)
        live: dict[str, _Live] = {}
        t_base = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t_base

        def finish(req: Request, error: str | None = None) -> None:
            req.error = error
            batcher.retire(req, now())
            metrics.record(req)
            live.pop(req.rid, None)

        while pending or batcher.waiting or batcher.running:
            t = now()
            while pending and pending[0].arrival_time <= t:
                batcher.submit(pending.pop(0))
            if not batcher.running and not batcher.waiting:
                # idle: sleep toward the next arrival, then re-check
                time.sleep(min(max(pending[0].arrival_time - t, 0.0), 0.02))
                continue

            for req in batcher.admit(now()):
                tokens = self._prompt(req)
                logits, cache = self._prefill(self.params, tokens, self._D)
                tok = int(jnp.argmax(logits[:, -1], axis=-1)[0])
                req.first_token_time = now()
                req.tokens.append(tok)
                if len(req.tokens) >= req.max_new_tokens:
                    finish(req)
                    continue
                live[req.rid] = _Live(cache=cache, tok=tok,
                                      rng=jax.random.key(req.prompt_seed))

            # one decode micro-step for every running request; the step's
            # expert jobs go to the pool as ONE concurrent batch
            batch = list(batcher.running)
            if not batch:
                continue
            jobs, operands, step_wall = [], {}, {}
            for req in batch:
                st = live[req.rid]
                ts = time.perf_counter()
                st.rng, sub = jax.random.split(st.rng)
                tok_arr, st.cache = self._decode(
                    self.params, st.cache,
                    jnp.asarray([[st.tok]], dtype=jnp.int32), sub, self._D)
                st.pending_tok = int(tok_arr[0, 0])
                step_wall[req.rid] = time.perf_counter() - ts
                job, x, W = self._expert_job(req, st.tok)
                jobs.append(job)
                operands[req.rid] = (x, W)

            for req, res in zip(batch, self.mux.run(jobs)):
                st = live[req.rid]
                if not res.ok:
                    finish(req, error=res.error)
                    continue
                x, W = operands[req.rid]
                if not self._exact(res.report.blocks, x, W):
                    finish(req, error="decoded expert product mismatch")
                    continue
                if res.report.workers_used < res.report.num_workers:
                    req.straggler_recoveries += 1
                req.token_latencies.append(step_wall[req.rid]
                                           + res.report.total_time)
                req.tokens.append(st.pending_tok)
                st.tok = st.pending_tok
                if len(req.tokens) >= req.max_new_tokens:
                    finish(req)
        return metrics

    # -------------------------------------------------------------------------

    def close(self) -> None:
        self.mux.close()

    def __enter__(self) -> "ServingEngine":
        self.mux.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()
