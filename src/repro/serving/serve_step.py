"""Serving steps: batched prefill + single-token decode with sampling.

``make_prefill_step`` / ``make_decode_step`` are the functions the dry-run
lowers for the prefill_32k / decode_32k / long_500k shapes: decode is ONE new
token against a KV/state cache of the shape's seq_len, exactly per the
assignment.
"""

from __future__ import annotations

import weakref
from functools import partial

import jax
import jax.numpy as jnp


def make_prefill_step(model, max_seq: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        extras = {k: v for k, v in batch.items() if k in ("frames", "vision")}
        logits, cache = model.prefill(params, batch["tokens"], extras=extras,
                                      max_seq=max_seq, cache_dtype=cache_dtype)
        return logits, cache
    return prefill_step


def make_decode_step(model, temperature: float = 0.0):
    def decode_step(params, cache, tokens, rng):
        logits, cache = model.decode_step(params, cache, tokens)
        last = logits[:, -1]
        if temperature > 0:
            next_tok = jax.random.categorical(rng, last / temperature, axis=-1)
        else:
            next_tok = jnp.argmax(last, axis=-1)
        return next_tok.astype(jnp.int32)[:, None], cache
    return decode_step


# model -> {temperature: jitted decode step}.  Weak keys: a model going out
# of scope must release its compiled executables, not pin them for the
# process lifetime.
_JITTED_DECODE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def jitted_decode_step(model, temperature: float = 0.0):
    """The jitted ``make_decode_step``, cached per (model, temperature).

    ``generate`` used to re-wrap ``jax.jit`` on every call, so every
    generate paid jit's dispatch-cache miss on a fresh callable (and
    re-traced after any cache eviction).  One jitted callable per (model,
    temperature) means repeated generate calls -- the serving engine's
    steady state -- reuse the same executable.
    """
    per_model = _JITTED_DECODE.setdefault(model, {})
    key = float(temperature)
    if key not in per_model:
        per_model[key] = jax.jit(make_decode_step(model, temperature))
    return per_model[key]


def generate(model, params, prompt, *, steps: int, max_seq: int,
             temperature: float = 0.0, extras=None, rng=None,
             cache_dtype=jnp.bfloat16):
    """Greedy/temperature generation loop (example/driver use)."""
    rng = rng if rng is not None else jax.random.key(0)
    logits, cache = model.prefill(params, prompt, extras=extras,
                                  max_seq=max_seq, cache_dtype=cache_dtype)
    decode = jitted_decode_step(model, temperature)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for i in range(steps - 1):
        rng, sub = jax.random.split(rng)
        tok, cache = decode(params, cache, tok, sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
