"""command-r-35b [dense]: GQA, no-bias, 256k vocab -- the largest C = A^T B
(lm head) among the assigned archs, and the primary coded-matmul showcase.
[hf:CohereForAI/c4ai-command-r-v01]"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22_528,
    vocab_size=256_000,
    norm="layernorm",
    tie_embeddings=True,      # command-r ties input/output embeddings
    sub_quadratic=False,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
))
