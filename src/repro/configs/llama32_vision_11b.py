"""llama-3.2-vision-11b [vlm]: GQA decoder with cross-attention image layers
every 5th layer; patch embeddings are a STUB (input_specs provides them).
[hf:meta-llama/Llama-3.2-11B-Vision]"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    cross_attn_every=5,       # slots 4, 9, ... are cross-attention layers
    vision_tokens=1601,       # 1 CLS + 40x40 patches (stubbed frontend)
    sub_quadratic=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
))
