"""Architecture configs: one module per assigned architecture.

Use ``repro.configs.get(name)`` / ``repro.configs.ARCHS`` for lookup.
"""

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, ARCH_REGISTRY, register

# importing the modules registers the configs
from repro.configs import (  # noqa: F401
    whisper_medium,
    rwkv6_3b,
    llama32_vision_11b,
    dbrx_132b,
    qwen3_moe_30b_a3b,
    internlm2_1_8b,
    starcoder2_7b,
    command_r_35b,
    qwen2_7b,
    jamba15_large_398b,
    sparse_code_demo,
)

ARCHS = dict(ARCH_REGISTRY)


def get(name: str) -> ArchConfig:
    try:
        return ARCH_REGISTRY[name]
    except KeyError as e:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCH_REGISTRY)}") from e
