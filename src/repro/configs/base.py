"""Architecture configuration schema.

Every assigned architecture is an ``ArchConfig``.  The model builder
(repro.models.registry) consumes only this schema, so new architectures are
pure config additions.  ``reduced()`` yields the small same-family variant
used by the CPU smoke tests (full configs are exercised only through the
dry-run's ShapeDtypeStructs).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.coded.config import CodedMatmulConfig


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                 # per-expert hidden width
    every: int = 1            # MoE on every k-th layer (jamba: 2), else dense MLP
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int           # decoder layers for encdec
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                 # dense-MLP hidden width (MoE archs: see moe.d_ff)
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // num_heads
    qkv_bias: bool = False
    mlp_bias: bool = False
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    act: str = "silu"         # silu (swiglu) | gelu (plain MLP)
    use_rope: bool = True
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: bool = False
    rwkv_head_size: int = 64
    attn_every: int = 1       # 1 = attention in every layer; jamba: 8
    cross_attn_every: int = 0  # vlm: every k-th layer is cross-attention
    encoder_layers: int = 0   # encdec only
    encoder_seq: int = 1500   # whisper frame embeddings (stub frontend)
    vision_tokens: int = 1024  # vlm patch embeddings (stub frontend)
    max_seq: int = 32_768
    sub_quadratic: bool = False  # may run long_500k
    remat: bool = True        # activation checkpointing per layer group
    source: str = ""          # provenance note [paper/hf; tier]

    # ---- framework optimization flags (default OFF = the recorded baseline;
    # ---- EXPERIMENTS.md section Perf measures each; see launch/roofline.py --opt)
    opt_fused_ce: bool = False         # hand-written CE backward (no dlogits AG)
    opt_moe_local_dispatch: bool = False  # dp-chunk-local MoE pack (no scatter replication)
    opt_onehot_cache: bool = False     # one-hot KV-cache update (no DUS gathers)
    opt_serving_layout: bool = False   # decode-time weight layout: shard the
    #   contraction dim over 'data' so per-token matmuls psum tiny partials
    #   instead of all-gathering FSDP-sharded weights every step
    opt_seq_parallel: bool = False     # sequence-sharded residual stream (train)
    opt_remat_save_tp: bool = False    # remat policy: save TP-psum'd block
    #   outputs so the backward recompute does not re-run forward all-reduces
    opt_moe_shardmap_combine: bool = False  # hand-written shard_map MoE
    #   combine: sum each expert shard's contributions locally, psum ONE
    #   (Tl, d) bf16 tensor (vs GSPMD's (Tl*k, d) f32 gather-AR)
    opt_coded_moe: bool = False        # coded expert FFN matmuls: every MoE
    #   expert product is encoded over `coded_moe_workers` redundant workers
    #   with the scheme in `coded` and decoded linearly, so generation
    #   tolerates dead/slow expert shards (models/moe.py, DESIGN.md s.11)
    coded_moe_workers: int = 0         # workers for the expert code; 0 ->
    #   num_experts + 2 (two redundant rows, the paper's minimal slack)
    # ---- coded-matmul deployment (repro.coded) --------------------------------
    # `coded` is the authoritative execution config for the coded matmul
    # device path (scheme, backend, decode layout, ...), validated at
    # construction against the scheme/backend registries -- new backends
    # registered in repro.core.coded_backends become legal values with no
    # change here.  `coded_backend` survives as the legacy backend alias:
    # its None default means "follow coded.backend" (so passing coded=
    # alone is never clobbered by the alias default), a string value
    # (init kwarg or dataclasses.replace) folds into `coded`, and reads
    # always see the mirrored `coded.backend`.  Caveat: because the
    # mirror is a stored string, `dataclasses.replace(cfg, coded=...)`
    # with a DIFFERENT backend re-folds the old alias -- change backend
    # via `coded_backend=` or `with_coded(...)`, which keeps both in sync.
    coded: CodedMatmulConfig = CodedMatmulConfig()
    coded_backend: Optional[str] = None

    def __post_init__(self):
        if (self.coded_backend is not None
                and self.coded_backend != self.coded.backend):
            # the alias was written: fold it into the authoritative config,
            # which validates the name against the live backend registry
            try:
                folded = dataclasses.replace(self.coded,
                                             backend=self.coded_backend)
            except ValueError as e:
                raise ValueError(f"coded_backend: {e}") from None
            object.__setattr__(self, "coded", folded)
        object.__setattr__(self, "coded_backend", self.coded.backend)

    def with_coded(self, **kw) -> "ArchConfig":
        """Replace fields of the embedded ``CodedMatmulConfig`` (keeping the
        ``coded_backend`` alias mirror consistent)."""
        new = dataclasses.replace(self.coded, **kw)
        return dataclasses.replace(self, coded=new, coded_backend=new.backend)

    def with_opts(self, names) -> "ArchConfig":
        valid = {"fused_ce", "moe_local_dispatch", "onehot_cache",
                 "serving_layout", "seq_parallel", "remat_save_tp",
                 "moe_shardmap_combine", "coded_moe"}
        kw = {}
        for nm in names:
            if nm not in valid:
                raise ValueError(f"unknown opt {nm!r}; options {sorted(valid)}")
            kw[f"opt_{nm}"] = True
        return dataclasses.replace(self, **kw)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def group_size(self) -> int:
        """Layers per scan group (the repeating heterogeneous unit)."""
        g = 1
        if self.attn_every > 1:
            g = self.attn_every
        if self.cross_attn_every > 0:
            g = max(g, self.cross_attn_every)
        if self.moe and self.moe.every > 1:
            import math
            g = math.lcm(g, self.moe.every)
        return g

    def layer_plan(self) -> list[tuple[str, str]]:
        """(mixer, ffn) for each slot in one scan group.

        mixer: attn | cross | mamba | rwkv;  ffn: mlp | moe.
        """
        plan = []
        for s in range(self.group_size):
            if self.rwkv:
                mixer = "rwkv"
            elif self.attn_every > 1:
                # jamba-style: one attention layer per group, rest mamba
                mixer = "attn" if s == self.attn_every // 2 else "mamba"
            elif self.cross_attn_every > 0 and (s + 1) % self.cross_attn_every == 0:
                mixer = "cross"
            else:
                mixer = "attn"
            if self.moe is not None and (s % self.moe.every == self.moe.every - 1):
                ffn = "moe"
            else:
                ffn = "mlp"
            plan.append((mixer, ffn))
        return plan

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.group_size == 0, (
            f"{self.name}: num_layers {self.num_layers} % group {self.group_size}")
        return self.num_layers // self.group_size

    def params_count(self) -> int:
        """Analytic parameter count (embeddings + layers + head)."""
        d, hd = self.d_model, self.hd
        qk = self.num_heads * hd
        kv = self.num_kv_heads * hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for mixer, ffn in self.layer_plan() * self.num_groups:
            if mixer in ("attn", "cross"):
                total += d * qk + 2 * d * kv + qk * d
                if mixer == "cross":
                    total += d * qk + 2 * d * kv + qk * d  # paired self-attn block
            elif mixer == "mamba":
                di = self.ssm.expand * d
                total += d * 2 * di + di * self.ssm.d_conv + di * (
                    2 * self.ssm.d_state + 2) + di * d
            elif mixer == "rwkv":
                hsz = self.rwkv_head_size
                total += 4 * d * d + d * hsz  # r,k,v,o (+gates approximated)
            if ffn == "moe":
                total += self.moe.num_experts * 3 * d * self.moe.d_ff + d * self.moe.num_experts
            else:
                n_mats = 3 if self.act == "silu" else 2
                total += n_mats * d * self.d_ff
            total += 2 * d  # norms
        if self.encoder_layers:
            total += self.encoder_layers * (2 * (d * qk + 2 * d * kv + qk * d) // 2
                                            + (3 if self.act == "silu" else 2) * d * self.d_ff
                                            + 2 * d)
        return int(total)

    def active_params_count(self) -> int:
        """Active parameters per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.params_count()
        full = self.params_count()
        moe_layers = sum(1 for _, f in self.layer_plan() if f == "moe") * self.num_groups
        per_expert = 3 * self.d_model * self.moe.d_ff
        inactive = moe_layers * (self.moe.num_experts - self.moe.top_k) * per_expert
        return int(full - inactive)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        g = self.group_size
        moe = None
        if self.moe:
            # capacity_factor = num_experts => capacity >= T * top_k: nothing
            # ever drops, so decode == forward exactly (the smoke suite checks
            # cache exactness; capacity drops are a train-time efficiency knob)
            moe = dataclasses.replace(self.moe, num_experts=min(4, self.moe.num_experts),
                                      top_k=min(2, self.moe.top_k), d_ff=64,
                                      capacity_factor=float(min(4, self.moe.num_experts)))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=g * 2 if self.family != "encdec" else g * 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(2, self.num_kv_heads),
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            moe=moe,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=16 if self.encoder_layers else self.encoder_seq,
            vision_tokens=16 if self.cross_attn_every else self.vision_tokens,
            rwkv_head_size=16 if self.rwkv else self.rwkv_head_size,
            max_seq=128,
        )


ARCH_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg
