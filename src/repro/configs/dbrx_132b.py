"""dbrx-132b [moe]: 16 experts top-4, fine-grained. [hf:databricks/dbrx-base]"""

from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10_752,              # unused (all layers MoE); kept for completeness
    vocab_size=100_352,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff=10_752, every=1),
    sub_quadratic=False,
    source="hf:databricks/dbrx-base; unverified",
))
