"""jamba-1.5-large-398b [hybrid]: Mamba + attention 1:7 interleave
(attn_layer_period=8, offset=4), MoE 16e top-2 every other layer.
[arXiv:2403.19887; hf]"""

from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,            # 9 groups of 8 (1 attn + 7 mamba each)
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24_576, every=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    sub_quadratic=True,       # SSM-dominant: runs long_500k
    source="arXiv:2403.19887; hf",
))
