"""starcoder2-7b [dense]: GQA, RoPE; 36 heads (non-divisible by TP=16 --
GSPMD pads, see DESIGN.md section 6). [arXiv:2402.19173; hf]"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_432,
    vocab_size=49_152,
    qkv_bias=True,
    mlp_bias=True,
    act="gelu",
    norm="layernorm",
    sub_quadratic=False,
    source="arXiv:2402.19173; hf",
))
