"""The paper's own experiment configuration (Section V): sparse Bernoulli
matrices, m = n = 4, N = 16+ workers -- used by benchmarks and examples,
not an LM architecture."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class SparseCodeExperiment:
    r: int = 150_000
    s: int = 150_000
    t: int = 150_000
    nnz_a: int = 600_000
    nnz_b: int = 600_000
    m: int = 4
    n: int = 4
    num_workers: int = 16
    num_stragglers: int = 2
    distribution: str = "wave_soliton"


PAPER_SQUARE = SparseCodeExperiment()
PAPER_TALL = SparseCodeExperiment(r=300_000, s=150_000, t=3_000_000)
PAPER_FAT = SparseCodeExperiment(r=150_000, s=300_000, t=150_000)

# CPU-budget variants used by the default benchmark run (same density
# regime, dimensions scaled so a full sweep finishes in seconds).
BENCH_SQUARE = SparseCodeExperiment(r=6000, s=6000, t=6000, nnz_a=24_000, nnz_b=24_000)
BENCH_TALL = SparseCodeExperiment(r=12_000, s=6000, t=24_000, nnz_a=24_000, nnz_b=24_000)
BENCH_FAT = SparseCodeExperiment(r=6000, s=12_000, t=6000, nnz_a=24_000, nnz_b=24_000)
