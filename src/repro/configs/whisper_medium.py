"""whisper-medium [audio]: enc-dec transformer backbone, conv frontend STUB
(input_specs provides precomputed frame embeddings).  [arXiv:2212.04356]"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    encoder_seq=1500,         # 30s of audio at 50Hz after the (stubbed) conv
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,          # MHA (GQA kv=16)
    d_ff=4096,
    vocab_size=51_865,
    qkv_bias=True,
    mlp_bias=True,
    norm="layernorm",
    act="gelu",
    use_rope=False,           # whisper uses absolute positions
    sub_quadratic=False,
    source="arXiv:2212.04356; unverified",
))
