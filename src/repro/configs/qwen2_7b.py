"""qwen2-7b [dense]: GQA, QKV bias; 28 heads (non-divisible by TP=16 --
GSPMD pads). [arXiv:2407.10671; hf]"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    sub_quadratic=False,
    source="arXiv:2407.10671; hf",
))
