"""qwen3-moe-30b-a3b [moe]: 128 experts top-8, fine-grained d_ff=768.
[hf:Qwen/Qwen3-30B-A3B]"""

from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,             # decoupled from d_model/num_heads (per HF config)
    d_ff=768,                 # per-expert width (fine-grained experts)
    vocab_size=151_936,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff=768, every=1),
    sub_quadratic=False,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
