"""rwkv6-3b [ssm]: Finch, attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""

from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,             # d_model / head_size(64)
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    rwkv=True,
    rwkv_head_size=64,
    use_rope=False,
    act="relu_sq",            # rwkv channel-mix uses relu^2
    sub_quadratic=True,       # linear in sequence: runs long_500k
    source="arXiv:2404.05892; hf",
))
