"""Checkpointing with sparse-code erasure redundancy.

Two layers:

* Plain versioned checkpointing: atomic manifest + per-shard .npz files,
  async save thread, resume-from-latest.  This is the boring-but-essential
  fault-tolerance substrate (restart after preemption).

* Coded redundancy (the paper, applied to storage): the flattened parameter
  vector is split into mn chunks; N > mn coded chunks
  ``c_k = sum_ij w^k_ij chunk_ij`` are written to *distinct* storage targets
  using the (P, S)-sparse code.  Restore succeeds from ANY full-rank subset
  (Theorem 2: w.h.p. any ~mn of N), decoded with the hybrid peeling/rooting
  decoder in O(nnz * ln(mn)) -- losing a storage node (or a pod's worth of
  shards) costs nothing.  Sparsity-awareness matters because compressed
  (top-k) gradient/optimizer states are genuinely sparse.
"""

from __future__ import annotations

import json
import pathlib
import threading
import time

import jax
import numpy as np

from repro.core.decoder import hybrid_decode
from repro.core.encoder import SparseCodeSpec, generate_coefficient_matrix, make_tasks


# ----------------------------- plain checkpoints -----------------------------

def _flatten(params):
    leaves, treedef = jax.tree.flatten(params)
    return [np.asarray(l) for l in leaves], treedef


def save_checkpoint(directory, step: int, params, opt_state=None,
                    extra: dict | None = None) -> pathlib.Path:
    """Atomic versioned save: write step dir, then flip the manifest."""
    directory = pathlib.Path(directory)
    step_dir = directory / f"step_{step:08d}"
    step_dir.mkdir(parents=True, exist_ok=True)
    leaves, _ = _flatten(params)
    np.savez(step_dir / "params.npz", *leaves)
    if opt_state is not None:
        oleaves, _ = _flatten(opt_state)
        np.savez(step_dir / "opt_state.npz", *oleaves)
    manifest = {"step": step, "time": time.time(), "extra": extra or {},
                "has_opt": opt_state is not None}
    tmp = directory / "manifest.json.tmp"
    tmp.write_text(json.dumps(manifest))
    tmp.replace(directory / "manifest.json")   # atomic flip
    return step_dir


def latest_step(directory) -> int | None:
    manifest = pathlib.Path(directory) / "manifest.json"
    if not manifest.exists():
        return None
    return json.loads(manifest.read_text())["step"]


def restore_checkpoint(directory, params_template, opt_template=None,
                       step: int | None = None):
    directory = pathlib.Path(directory)
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    step_dir = directory / f"step_{step:08d}"
    _, treedef = jax.tree.flatten(params_template)
    with np.load(step_dir / "params.npz") as z:
        leaves = [z[f"arr_{i}"] for i in range(len(z.files))]
    params = jax.tree.unflatten(treedef, leaves)
    out = (params,)
    if opt_template is not None:
        _, otreedef = jax.tree.flatten(opt_template)
        with np.load(step_dir / "opt_state.npz") as z:
            oleaves = [z[f"arr_{i}"] for i in range(len(z.files))]
        out += (jax.tree.unflatten(otreedef, oleaves),)
    return out + (step,)


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (training never blocks
    on storage); `wait()` before exit."""

    def __init__(self, directory):
        self.directory = pathlib.Path(directory)
        self._thread: threading.Thread | None = None

    def save(self, step: int, params, opt_state=None, extra=None):
        params = jax.tree.map(np.asarray, params)  # snapshot on caller thread
        opt_state = jax.tree.map(np.asarray, opt_state) if opt_state else None
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, params, opt_state, extra), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


# --------------------------- coded redundancy --------------------------------

def save_coded_checkpoint(directory, step: int, params, *, m: int = 4, n: int = 4,
                          num_targets: int = 24, seed: int = 0,
                          distribution: str = "wave_soliton") -> dict:
    """Erasure-code the checkpoint across `num_targets` storage shards.

    Returns the manifest (also written to disk).  Each target file holds one
    coded chunk; any full-rank subset of targets restores the checkpoint.
    """
    directory = pathlib.Path(directory)
    cdir = directory / f"coded_{step:08d}"
    cdir.mkdir(parents=True, exist_ok=True)
    leaves, _ = _flatten(params)
    flat = np.concatenate([l.reshape(-1).astype(np.float32) for l in leaves])
    d = m * n
    pad = (-len(flat)) % d
    flat = np.pad(flat, (0, pad))
    chunks = flat.reshape(d, -1)

    spec = SparseCodeSpec(m=m, n=n, num_workers=num_targets,
                          distribution=distribution, seed=seed)
    M = generate_coefficient_matrix(spec)
    for k, task in enumerate(make_tasks(M)):
        coded = np.zeros(chunks.shape[1], np.float32)
        for c, w in zip(task.cols, task.weights):
            coded += w * chunks[c]
        np.savez_compressed(cdir / f"target_{k:03d}.npz", coded=coded)
    manifest = {
        "step": step, "m": m, "n": n, "num_targets": num_targets,
        "pad": int(pad), "total": int(len(flat)),
        "M_rows": M.toarray().tolist(),
        "leaf_shapes": [list(l.shape) for l in leaves],
        "leaf_dtypes": [str(l.dtype) for l in leaves],
    }
    (cdir / "coded_manifest.json").write_text(json.dumps(manifest))
    return manifest


def restore_coded_checkpoint(directory, step: int, params_template,
                             available: list[int] | None = None):
    """Restore from any decodable subset of targets.

    available: indices of surviving target files (None = all on disk).
    Raises DecodingError if the surviving coefficient rows lose full rank.
    """
    import scipy.sparse as sp

    directory = pathlib.Path(directory)
    cdir = directory / f"coded_{step:08d}"
    manifest = json.loads((cdir / "coded_manifest.json").read_text())
    M_full = np.asarray(manifest["M_rows"])
    if available is None:
        available = [int(p.stem.split("_")[1]) for p in sorted(cdir.glob("target_*.npz"))]
    rows = sorted(available)
    M = sp.csr_matrix(M_full[rows])
    results = []
    for k in rows:
        with np.load(cdir / f"target_{k:03d}.npz") as z:
            results.append(z["coded"])
    blocks, stats = hybrid_decode(M, results)
    flat = np.concatenate(blocks)
    if manifest["pad"]:
        flat = flat[: -manifest["pad"]]
    _, treedef = jax.tree.flatten(params_template)
    leaves_t = jax.tree.leaves(params_template)
    out, off = [], 0
    for shape, dtype, tmpl in zip(manifest["leaf_shapes"],
                                  manifest["leaf_dtypes"], leaves_t):
        size = int(np.prod(shape)) if shape else 1
        out.append(flat[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, out), stats
