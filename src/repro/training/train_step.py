"""Train step factory: loss -> grads -> clip -> AdamW, one jittable function.

The returned step is what the dry-run lowers and what train.py runs; its
in/out shardings come from Model.specs() (params & optimizer state mirror
each other: FSDP over 'data', TP over 'model', batch over ('pod','data')).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamW, apply_updates, clip_by_global_norm


def make_train_step(model, optimizer: AdamW, clip_norm: float = 1.0):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = {"loss": loss.astype(jnp.float32),
                   "grad_norm": gnorm.astype(jnp.float32)}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        return model.loss(params, batch).astype(jnp.float32)
    return eval_step
