"""Gradient compression: top-k sparsification + error feedback, with coded
sparse aggregation.

At 1000+ node scale the gradient all-reduce is DCN-bound across pods.  The
standard mitigation is top-k sparsification with error feedback (the residual
is carried into the next step, preserving convergence).  Sparsified gradients
are exactly the regime the paper targets -- nnz << size -- so aggregating
them through the (P, S)-sparse code gives pod-failure tolerance at
O(nnz * ln(mn)) decode cost (``coded_aggregate`` simulates the pod-level
protocol on host; on a real fleet each "row" is one pod's contribution over
DCN).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoder import hybrid_decode
from repro.core.encoder import SparseCodeSpec, generate_coefficient_matrix, make_tasks


def topk_sparsify(tree, frac: float):
    """Keep the top `frac` fraction of entries (by magnitude) per leaf.
    Returns (sparse_tree, residual_tree)."""
    def one(g):
        flat = g.reshape(-1)
        k = max(1, int(flat.size * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(g) >= thresh).astype(g.dtype)
        return g * mask, g * (1 - mask)
    kept, resid = [], []
    leaves, treedef = jax.tree.flatten(tree)
    for g in leaves:
        a, b = one(g)
        kept.append(a)
        resid.append(b)
    return jax.tree.unflatten(treedef, kept), jax.tree.unflatten(treedef, resid)


def error_feedback_update(grads, residual, frac: float):
    """grads + carried residual -> (compressed grads, new residual)."""
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)
    corrected = jax.tree.map(lambda g, r: g + r.astype(g.dtype), grads, residual)
    return topk_sparsify(corrected, frac)


def coded_aggregate(grad_shards: list[np.ndarray], *, m: int = 2, n: int = 2,
                    num_workers: int | None = None, seed: int = 0,
                    survivors: list[int] | None = None):
    """Sum sparse gradient shards through the (P,S)-sparse code.

    grad_shards: per-pod flat gradient vectors (the quantities a plain DCN
    all-reduce would sum).  The sum is block-partitioned into mn pieces; each
    of N aggregator nodes combines its assigned coded pieces; any full-rank
    subset of aggregators reconstructs the sum.  Returns (summed_vector,
    decode_stats).
    """
    total = np.sum(grad_shards, axis=0)  # what aggregators jointly compute
    d = m * n
    pad = (-len(total)) % d
    padded = np.pad(total, (0, pad))
    chunks = padded.reshape(d, -1)

    N = num_workers or (d + 4)
    spec = SparseCodeSpec(m=m, n=n, num_workers=N, seed=seed)
    M = generate_coefficient_matrix(spec)
    results = []
    for task in make_tasks(M):
        acc = np.zeros(chunks.shape[1], np.float32)
        for c, w in zip(task.cols, task.weights):
            acc += w * chunks[c]
        results.append(acc)

    rows = sorted(survivors) if survivors is not None else list(range(N))
    blocks, stats = hybrid_decode(M[rows], [results[r] for r in rows])
    out = np.concatenate(blocks)
    if pad:
        out = out[:-pad]
    return out, stats
