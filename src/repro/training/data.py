"""Data pipeline: deterministic synthetic LM streams + stub modality inputs.

Production shape: an infinite, shardable iterator of already-tokenized
batches.  The synthetic stream is a fixed-seed Zipf-ish token process (cheap,
deterministic, no I/O) -- the framework treats it exactly like a real corpus
reader; swap `SyntheticCorpus` for a file-backed reader with the same
interface to train on real data.  Modality frontends are STUBS per the
assignment: `frames` / `vision` are precomputed embeddings drawn from the
same deterministic stream.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticCorpus:
    cfg: object                  # ArchConfig
    batch: int
    seq: int
    seed: int = 0
    dtype: object = np.float32   # embeddings dtype for stub modalities

    def __iter__(self):
        step = 0
        while True:
            yield self.make_batch(step)
            step += 1

    def make_batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        V = cfg.vocab_size
        # Zipf-ish marginal so the loss has realistic structure
        ranks = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        tokens_all = np.minimum(ranks, V - 1).astype(np.int32)
        out = {
            "tokens": tokens_all[:, :-1],
            "labels": tokens_all[:, 1:],
        }
        if cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (self.batch, cfg.encoder_seq, cfg.d_model)).astype(self.dtype) * 0.02
        elif cfg.family == "vlm":
            out["vision"] = rng.standard_normal(
                (self.batch, cfg.vision_tokens, cfg.d_model)).astype(self.dtype) * 0.02
        return out


def input_specs(cfg, batch: int, seq: int, dtype="bfloat16", kind: str = "train"):
    """ShapeDtypeStructs for every model input (dry-run stand-ins).

    kind: train -> tokens+labels(+modality); prefill -> tokens(+modality);
    decode -> one token (cache specs come from Model.init_cache shapes).
    """
    import jax.numpy as jnp

    emb_dtype = jnp.dtype(dtype)
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if kind == "train":
        out = {"tokens": tok, "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    elif kind == "prefill":
        out = {"tokens": tok}
    elif kind == "decode":
        out = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    else:
        raise ValueError(kind)
    if kind != "decode":
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((batch, cfg.encoder_seq, cfg.d_model), emb_dtype)
        elif cfg.family == "vlm":
            out["vision"] = jax.ShapeDtypeStruct((batch, cfg.vision_tokens, cfg.d_model), emb_dtype)
    return out
