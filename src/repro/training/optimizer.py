"""AdamW + schedules + gradient clipping (pure-pytree, dependency-free).

Optimizer state mirrors the parameter pytree, so GSPMD shards it with the
same PartitionSpecs (FSDP over 'data', TP over 'model') -- the ZeRO pattern.
``state_dtype`` lets the m/v moments live in bf16: that halves the optimizer
memory term for the biggest archs (see EXPERIMENTS.md section Perf, memory
hillclimb) at a small quality cost that is standard practice at scale.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    state_dtype: object = None  # None -> same as param dtype

    def init(self, params):
        def zeros(p):
            dt = self.state_dtype or p.dtype
            return jnp.zeros_like(p, dtype=dt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def _lr(self, count):
        return self.lr(count) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state, params):
        count = state["count"] + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)
        lr = self._lr(count)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            mhat = m_new / bc1
            vhat = v_new / bc2
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return ((-lr * step).astype(p.dtype),
                    m_new.astype(m.dtype), v_new.astype(v.dtype))

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        new_state = {
            "m": treedef.unflatten([o[1] for o in out]),
            "v": treedef.unflatten([o[2] for o in out]),
            "count": count,
        }
        return updates, new_state


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), gn


def cosine_warmup_schedule(peak_lr: float, warmup: int, total: int,
                           floor: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup, 1)
        frac = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(c < warmup, warm, cos)
    return lr
