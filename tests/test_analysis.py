"""Tests for the ``repro.analysis`` static checker: every lint rule fires on
its planted fixture and stays quiet on the clean twin, the jaxpr passes
detect what they claim to detect, the scheme validator flags planted
violations, and -- the meta-test -- the live repo itself passes the full
CLI under ``--strict``.

The lint fixtures live in ``tests/analysis_fixtures/`` laid out like the
real package so the default ``LintConfig`` path rules apply verbatim.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest
import scipy.sparse as sp

from repro.analysis.findings import ERROR, WARNING, Finding, Report
from repro.analysis.lint import LintConfig, lint_source, run_lint

FIXTURES = Path(__file__).parent / "analysis_fixtures"
REPO = Path(__file__).resolve().parent.parent


# --------------------------------- findings ----------------------------------

def test_report_exit_codes():
    ok = Report(checked={"lint": 3})
    assert ok.exit_code() == 0 and ok.exit_code(strict=True) == 0
    warn = Report(findings=[Finding("r", WARNING, "f.py", 1, "m", "lint")],
                  checked={"lint": 3})
    assert warn.exit_code() == 0
    assert warn.exit_code(strict=True) == 1
    err = Report(findings=[Finding("r", ERROR, "f.py", 1, "m", "lint")],
                 checked={"lint": 3})
    assert err.exit_code() == 1
    vacuous = Report(checked={"jaxpr": 0})
    assert vacuous.exit_code() == 2  # checked nothing must not read as a pass


def test_finding_rejects_unknown_severity():
    with pytest.raises(ValueError, match="severity"):
        Finding("r", "fatal", "f.py", 1, "m", "lint")


# ------------------------------- lint fixtures -------------------------------

def test_fixture_tree_findings_match_plants_exactly():
    findings, files = run_lint(FIXTURES)
    assert files == 11
    got = sorted((f.path, f.line, f.rule) for f in findings)
    assert got == [
        ("bad_compat.py", 3, "compat-boundary"),
        ("bad_compat.py", 10, "compat-boundary"),
        ("bad_deprecated.py", 4, "no-deprecated-surface"),
        ("bad_deprecated.py", 8, "no-deprecated-surface"),
        ("bad_unused_waiver.py", 7, "unused-waiver"),
        ("coded/config.py", 4, "jax-free-module"),
        ("runtime/bad_rank.py", 7, "matrix-rank-hot-path"),
    ]
    assert all(f.severity == ERROR for f in findings)
    # a tree with planted violations fails the aggregate report
    assert Report(findings=list(findings),
                  checked={"lint": files}).exit_code() == 1


@pytest.mark.parametrize("rel", [
    "ok_compat.py", "compat.py", "kernels/fused.py", "core/encoder.py",
    "runtime/ok_rank.py", "ok_deprecated.py",
])
def test_clean_twins_stay_clean(rel):
    assert lint_source(rel, (FIXTURES / rel).read_text()) == []


@pytest.mark.parametrize("rel", [
    "bad_compat.py", "coded/config.py", "runtime/bad_rank.py",
    "bad_unused_waiver.py", "bad_deprecated.py",
])
def test_each_planted_fixture_fires(rel):
    assert lint_source(rel, (FIXTURES / rel).read_text())


def test_pallas_only_allowed_under_kernels():
    src = (FIXTURES / "kernels/fused.py").read_text()
    findings = lint_source("runtime/fused.py", src)
    assert {f.rule for f in findings} == {"compat-boundary"}


def test_waiver_trailing_and_above_line_both_work():
    above = ("import numpy as np\n"
             "# repro: allow(matrix-rank-hot-path)\n"
             "r = np.linalg.matrix_rank(M)\n")
    trailing = ("import numpy as np\n"
                "r = np.linalg.matrix_rank(M)"
                "  # repro: allow(matrix-rank-hot-path)\n")
    for src in (above, trailing):
        assert lint_source("runtime/x.py", src) == []


def test_waiver_for_wrong_rule_is_unused_and_does_not_suppress():
    src = ("import numpy as np\n"
           "# repro: allow(compat-boundary)\n"
           "r = np.linalg.matrix_rank(M)\n")
    rules = sorted(f.rule for f in lint_source("runtime/x.py", src))
    assert rules == ["matrix-rank-hot-path", "unused-waiver"]


def test_live_repo_waiver_is_used():
    # the sanctioned one-shot rank check in the registry: waived, not silent
    src = (REPO / "src/repro/coded/registry.py").read_text()
    assert "repro: allow(matrix-rank-hot-path)" in src
    assert lint_source("coded/registry.py", src) == []


def test_lint_flags_unparseable_source():
    findings = lint_source("x.py", "def broken(:\n")
    assert [f.rule for f in findings] == ["syntax"]


# ------------------------------- jaxpr passes --------------------------------

def test_stacked_detector_and_sensitivity_probe():
    jax = pytest.importorskip("jax")
    from repro.analysis.jaxpr_check import (
        assert_detector_sensitivity,
        legacy_stacked_gather,
        stacked_intermediates,
    )
    import jax.numpy as jnp

    L, s, n, bt = 5, 16, 2, 8
    closed = jax.make_jaxpr(
        lambda b: legacy_stacked_gather(b, L, s, n, bt))(
            jnp.ones((s, n * bt), jnp.float32))
    assert stacked_intermediates(closed.jaxpr, L * s)
    assert_detector_sensitivity(L, s, n, bt)  # must not raise
    clean = jax.make_jaxpr(lambda b: b @ b.T)(jnp.ones((s, n * bt)))
    assert stacked_intermediates(clean.jaxpr, L * s) == []


def test_collective_axis_pass():
    jax = pytest.importorskip("jax")
    from repro.analysis.jaxpr_check import (
        collective_axis_offenders,
        collective_prims,
    )
    import jax.numpy as jnp

    # an AbstractMesh stages a real 8-way shard_map without any devices
    # (a 1-device mesh's psum would be elided at trace time, and vmap
    # resolves axis names positionally)
    from jax.sharding import AbstractMesh
    from jax.sharding import PartitionSpec as P

    from repro import compat

    mesh = AbstractMesh((("model", 8),))
    f = compat.shard_map(lambda x: jax.lax.psum(x, "model"), mesh=mesh,
                         in_specs=P("model"), out_specs=P())
    closed = jax.make_jaxpr(f)(jnp.ones((8, 4), jnp.float32))
    assert collective_prims(closed.jaxpr) == ["psum2"]
    assert collective_axis_offenders(closed.jaxpr, "model") == []
    assert collective_axis_offenders(closed.jaxpr, "data") == [
        ("psum2", ("model",))]


def test_float64_pass():
    jax = pytest.importorskip("jax")
    from repro.analysis.jaxpr_check import float64_offenders
    import jax.numpy as jnp

    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(
            lambda x: jnp.sum(x * 2.0))(np.ones((4,), np.float64))
        assert float64_offenders(closed.jaxpr)
    clean = jax.make_jaxpr(
        lambda x: jnp.sum(x * 2.0))(np.ones((4,), np.float32))
    assert float64_offenders(clean.jaxpr) == []


def test_peak_bytes_pass():
    jax = pytest.importorskip("jax")
    from repro.analysis.jaxpr_check import peak_equation_bytes
    import jax.numpy as jnp

    closed = jax.make_jaxpr(
        lambda a, b: a @ b)(jnp.ones((8, 4), jnp.float32),
                            jnp.ones((4, 2), jnp.float32))
    peak, prim, shapes = peak_equation_bytes(closed.jaxpr)
    assert prim == "dot_general"
    assert peak == 4 * (8 * 4 + 4 * 2 + 8 * 2)


# ------------------------------ scheme validator -----------------------------

def test_scheme_validator_clean_on_builtin():
    from repro.analysis.schemes import validate_scheme

    assert validate_scheme("sparse_code") == []


def test_scheme_validator_flags_false_exactness_claim():
    from repro.analysis.schemes import validate_scheme
    from repro.coded import registry
    from repro.core import schemes as schemes_lib
    from repro.core.schemes import SchemeInvariants

    name = "bad_exact_claim"
    registry.register_scheme(
        name,
        lambda m, n, N, *, seed=0: schemes_lib.sparse_code(m, n, N, seed=seed),
        invariants=SchemeInvariants(exact=True, mean_overhead=0.0,
                                    max_overhead=0.0))
    try:
        rules = {f.rule for f in validate_scheme(name)}
        assert "recovery-threshold" in rules
    finally:
        registry._REGISTRY.pop(name, None)


def test_scheme_validator_flags_empty_generator_rows():
    from repro.analysis.schemes import validate_scheme
    from repro.coded import registry
    from repro.core.schemes import CodeInstance

    def degenerate(m, n, N, *, seed=0):
        # N workers but only mn useful rows: the rest are EMPTY
        M = sp.csr_matrix(np.eye(N, m * n))
        return CodeInstance(name="degenerate", M=M,
                            worker_rows=[[k] for k in range(N)],
                            cost_factor=np.ones(N), decode_kind="hybrid")

    name = "bad_empty_rows"
    registry.register_scheme(name, degenerate)
    try:
        rules = {f.rule for f in validate_scheme(name)}
        assert "degree-sanity" in rules
    finally:
        registry._REGISTRY.pop(name, None)


def test_scheme_validator_findings_anchor_at_builder():
    from repro.analysis.schemes import validate_scheme
    from repro.coded import registry
    from repro.core.schemes import SchemeInvariants

    name = "bad_anchored"
    registry.register_scheme(
        name,
        lambda m, n, N, *, seed=0: registry.get_scheme(
            "sparse_code").instance(m, n, N, seed=seed),
        invariants=SchemeInvariants(exact=True, mean_overhead=0.0,
                                    max_overhead=0.0))
    try:
        findings = validate_scheme(name)
        assert findings
        # the anchor is THIS test file (where the builder lambda lives)
        assert all(f.path.endswith("test_analysis.py") for f in findings)
        assert all(f.line > 0 for f in findings)
    finally:
        registry._REGISTRY.pop(name, None)


# --------------------------------- meta-test ---------------------------------

def test_live_repo_passes_strict_cli(tmp_path):
    """The acceptance gate itself: the full CLI, exactly as CI invokes it,
    exits 0 on this repo with every layer reporting real coverage."""
    out = tmp_path / "findings.json"
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict",
         "--json", str(out)],
        capture_output=True, text=True, timeout=560, env=env, cwd=str(REPO))
    assert proc.returncode == 0, (
        f"repo fails its own strict analysis gate:\n{proc.stdout}\n"
        f"{proc.stderr}")
    report = json.loads(out.read_text())
    assert report["errors"] == 0 and report["warnings"] == 0
    checked = report["checked"]
    assert checked["lint"] >= 60       # the whole src/repro tree
    assert checked["schemes"] == 7     # every registered scheme
    assert checked["jaxpr"] >= 20      # both backends x layouts x schemes


def test_cli_only_lint_is_fast_and_scoped(tmp_path):
    out = tmp_path / "findings.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--only", "lint",
         "--json", str(out)],
        capture_output=True, text=True, timeout=120, env=env, cwd=str(REPO))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    checked = json.loads(out.read_text())["checked"]
    assert set(checked) == {"lint"}
