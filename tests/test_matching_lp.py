import numpy as np
import pytest

from repro.core import degree as dg
from repro.core.lp_design import optimize_degree_distribution
from repro.core.matching import (
    degree_evolution,
    empirical_matching_prob,
    perfect_matching_prob,
)


def test_degree_evolution_rows_are_distributions():
    p = dg.wave_soliton(12)
    E = degree_evolution(p)
    for s in range(1, 13):
        np.testing.assert_allclose(E[s].sum(), 1.0, atol=1e-12)
        assert np.all(E[s] >= -1e-15)


def test_degree_evolution_terminal():
    # P^(d) = P with p_0 = 0; P^(0) is a point mass at 0.
    p = dg.wave_soliton(8)
    E = degree_evolution(p)
    np.testing.assert_allclose(E[8, 1:9], p)
    assert E[8, 0] == 0.0
    np.testing.assert_allclose(E[0, 0], 1.0)


def test_matching_prob_in_unit_interval_and_monotone_signal():
    # Wave soliton (avg degree ~ln d) should beat the degree-1-only
    # distribution (balls in bins) by orders of magnitude under (48).
    d = 16
    p_wave = dg.wave_soliton(d)
    p_one = np.zeros(d); p_one[0] = 1.0
    hi = perfect_matching_prob(p_wave)
    lo = perfect_matching_prob(p_one)
    assert 0.0 <= lo < hi <= 1.0
    # for degree-1-only, (48) is exactly d!/d^d (balls in bins) -- check it
    import math
    assert np.isclose(lo, math.factorial(d) / d**d, rtol=1e-9)
    assert hi > 100 * lo


def test_formula_48_underestimates_truth():
    """Reproduction finding: the paper's 'exact' formula (48) is a greedy
    sequential approximation and substantially underestimates the Monte-Carlo
    ground truth (documented in EXPERIMENTS.md)."""
    d = 16
    p = dg.wave_soliton(d)
    analytic = perfect_matching_prob(p)
    emp = empirical_matching_prob(p, trials=300, rng=np.random.default_rng(0))
    assert emp > 0.5, "true matching probability is high at d=16"
    assert analytic < emp - 0.3, "(48) should sit far below the truth"


def test_lp_design_feasible_and_light():
    d = 16
    p = optimize_degree_distribution(d, method="lp")
    assert np.isclose(p.sum(), 1.0)
    avg = dg.average_degree(p)
    # must stay below dense (mn) and within the paper's ballpark (<~ RSD)
    assert avg < dg.average_degree(dg.robust_soliton(d)) + 1.0
    assert avg < d / 2


def test_hybrid_design_validates_matching_empirically():
    d = 16
    p = optimize_degree_distribution(d, method="hybrid", p_m=0.70, mc_trials=150)
    assert np.isclose(p.sum(), 1.0)
    emp = empirical_matching_prob(p, trials=200, rng=np.random.default_rng(1))
    assert emp >= 0.60  # cleared the (noisy) bar
    # average degree stays light: comparable to Table IV's 2.98 for mn=16
    assert dg.average_degree(p) < 5.5


def test_slsqp_design_runs_and_is_valid():
    # paper-literal program; may fall back to LP when (48) makes it infeasible
    d = 9
    p = optimize_degree_distribution(d, method="slsqp", p_m=0.05)
    assert np.isclose(p.sum(), 1.0)
    assert np.all(p >= -1e-12)
    assert dg.average_degree(p) < 5.0
