"""Numerics check for the shard_map MoE combine (opt_moe_shardmap_combine)
against the vmapped baseline, on an 8-device (2 data x 4 model) mesh.
Run by tests/test_opt_paths.py in a subprocess."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import compat
from repro.launch import meshctx
from repro.models import build


def main():
    mesh = compat.make_mesh((2, 4), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))
    base = configs.get("qwen3-moe-30b-a3b").reduced()
    # E=4 divisible by tp=4; batch*seq divisible by dp=2
    cfgs = {
        "baseline": dataclasses.replace(base, opt_moe_local_dispatch=True),
        "shardmap": dataclasses.replace(base, opt_moe_local_dispatch=True,
                                        opt_moe_shardmap_combine=True),
    }
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, base.vocab_size, size=(2, 16)), jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1)

    outs = {}
    with meshctx.use_mesh(mesh):
        for name, cfg in cfgs.items():
            model = build(cfg)
            params = model.init(jax.random.key(0), jnp.float32)
            loss, grads = jax.jit(jax.value_and_grad(model.loss))(
                params, {"tokens": tokens, "labels": labels})
            outs[name] = (float(loss), grads)

    l0, g0 = outs["baseline"]
    l1, g1 = outs["shardmap"]
    assert abs(l0 - l1) / abs(l0) < 2e-3, (l0, l1)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-2, rtol=5e-2)  # bf16 psum path
    print("ALL-OK", l0, l1)


if __name__ == "__main__":
    main()
