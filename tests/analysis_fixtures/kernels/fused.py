"""Clean: jax.experimental.pallas is the kernel substrate, allowed under
kernels/ (compat deliberately does not wrap it)."""

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def grid(n):
    return pl.cdiv(n, 8), pltpu
