"""PLANTED: no-deprecated-surface violations -- import AND call of the
legacy coded_matmul shim."""

from repro.core.coded_matmul import coded_matmul  # line 4: violation


def run(A, B, plan, mesh):
    return coded_matmul(A, B, plan, mesh)  # line 8: violation
