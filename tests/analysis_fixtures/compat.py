"""The compat module ITSELF may touch version-gated APIs (rule exemption)."""

try:
    from jax.experimental.shard_map import shard_map
except ImportError:
    from jax import shard_map


def make_mesh(shape, names):
    import jax

    return jax.make_mesh(shape, names)
