"""Clean twin: a declared jax-free module using the sanctioned lazy-import
pattern (function-local jax import is fine)."""

import numpy as np


def to_device(x):
    import jax.numpy as jnp

    return jnp.asarray(np.asarray(x))
