"""PLANTED: compat-boundary violations (experimental import + gated attr)."""

from jax.experimental import shard_map  # line 3: violation


def build(devices):
    mesh = __import__("jax").make_mesh  # noqa: F841
    import jax

    return jax.make_mesh((len(devices),), ("model",))  # line 10: violation
