"""PLANTED: jax-free-module violation -- this path declares itself
importable before XLA_FLAGS, yet imports jax at module scope."""

import jax.numpy as jnp  # line 4: violation

DEFAULT = jnp.float32
