"""Clean twin: a one-shot construction-time rank check carries a waiver."""

import numpy as np


def build_plan(M, d):
    # repro: allow(matrix-rank-hot-path)
    return np.linalg.matrix_rank(M) >= d
