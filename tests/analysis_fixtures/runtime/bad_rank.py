"""PLANTED: matrix-rank-hot-path violation -- per-event rank recompute."""

import numpy as np


def on_worker_done(M, rows):
    return np.linalg.matrix_rank(M[rows]) >= M.shape[1]  # line 7: violation
