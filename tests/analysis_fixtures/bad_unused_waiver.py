"""PLANTED: a waiver that suppresses nothing is itself an error."""

import numpy as np


def harmless(x):
    return np.asarray(x)  # repro: allow(matrix-rank-hot-path)
