"""Clean twin of bad_compat: version-gated APIs reached through compat."""

from repro import compat


def build(devices):
    return compat.make_mesh((len(devices),), ("model",))
