"""Clean twin: the supported surface (CodedOp plan -> bind -> apply)."""

from repro.coded import CodedMatmulConfig, from_plan


def run(A, B, plan, mesh):
    op = from_plan(CodedMatmulConfig(), plan).bind(mesh)
    return op.apply(A, B)
