"""Chunk-granular protocol: decode parity, incremental rank tracker, and the
partial-straggler runtime (ISSUE 4 tentpole layers).

Parity is checked at two strengths, deliberately:

* **bit-identical** where every decode op is exact -- integer blocks with
  unit (+-1) weights through peel-only schedules multiply/divide by +-1 and
  add integers, so full-task and chunked decode must agree to the last bit;
* **allclose** across the WHOLE scheme registry (including float-weighted
  dense codes, whose pinv decodes legitimately differ in ulps between the
  atomic and the chunk-expanded system).
"""

import numpy as np
import pytest
import scipy.sparse as sp

from repro.coded import get_scheme, scheme_names
from repro.core import chunk_expand, chunk_slices, IncrementalRankTracker
from repro.core.encoder import CodedTask, SparseCodeSpec, generate_coefficient_matrix
from repro.core.schemes import ChunkedCode
from repro.runtime import (
    LogNormalRates,
    SlowWorkerRates,
    SlowWorkers,
    run_coded_job,
)


def _int_blocks(rng, d, shape=(4, 5)):
    """Integer-valued blocks: all decode arithmetic stays exact in f64."""
    return [rng.integers(-9, 10, size=shape).astype(np.float64)
            for _ in range(d)]


def _chunk_results(chunked: ChunkedCode, blocks):
    """Exact per-expanded-row results straight from the expanded M."""
    M = chunked.M
    out = {}
    for r in range(M.shape[0]):
        lo, hi = M.indptr[r], M.indptr[r + 1]
        if hi == lo:
            continue
        acc = None
        for c, w in zip(M.indices[lo:hi], M.data[lo:hi]):
            term = blocks[c] * w
            acc = term if acc is None else acc + term
        out[r] = acc
    return out


def _random_decodable_prefixes(chunked: ChunkedCode, rng, tries=200):
    """A random prefix-closed decodable chunk subset, as arrival pairs."""
    N, q = chunked.num_workers, chunked.num_chunks
    for _ in range(tries):
        progress = rng.integers(0, q + 1, size=N)
        pairs = [(w, c) for w in range(N) for c in range(int(progress[w]))]
        if chunked.can_decode(pairs):
            return pairs
    # fall back to everything (always decodable for a full-rank code)
    return [(w, c) for w in range(N) for c in range(q)]


# ------------------------------ chunk plumbing ------------------------------

def test_chunk_slices_partition():
    for length in (0, 1, 5, 7, 12):
        for q in (1, 2, 3, 5, 9):
            sls = chunk_slices(length, q)
            assert len(sls) == q
            flat = [i for sl in sls for i in range(sl.start, sl.stop)]
            assert flat == list(range(length))
            sizes = [sl.stop - sl.start for sl in sls]
            assert max(sizes) - min(sizes) <= 1


def test_coded_task_chunks_cover_task():
    rng = np.random.default_rng(0)
    task = CodedTask(worker=3, cols=np.arange(7), weights=rng.random(7))
    chunks = task.chunks(3)
    assert [c.chunk for c in chunks] == [0, 1, 2]
    np.testing.assert_array_equal(
        np.concatenate([c.cols for c in chunks]), task.cols)
    np.testing.assert_array_equal(
        np.concatenate([c.weights for c in chunks]), task.weights)


@pytest.mark.parametrize("q", [1, 2, 4])
def test_chunk_expand_rows_sum_to_original(q):
    spec = SparseCodeSpec(m=3, n=3, num_workers=20, seed=2)
    M = generate_coefficient_matrix(spec)
    Mq = chunk_expand(M, q)
    assert Mq.shape == (M.shape[0] * q, M.shape[1])
    # summing each row's chunk rows reproduces the row exactly
    S = sp.kron(sp.identity(M.shape[0]), np.ones((1, q)))
    np.testing.assert_array_equal((S @ Mq).toarray(), M.toarray())


# ------------------------------ decode parity -------------------------------

@pytest.mark.parametrize("scheme", sorted(scheme_names()))
@pytest.mark.parametrize("q", [1, 2, 4])
def test_chunked_decode_parity_all_schemes(scheme, q):
    """Any decodable prefix-closed chunk subset decodes to the true blocks,
    for every registered scheme (chunking passes through the registry)."""
    m, n = 2, 2
    sch = get_scheme(scheme)
    inst = (sch.instance(m, n) if scheme == "uncoded"
            else sch.instance(m, n, 12, seed=3))
    chunked = inst.chunked(q)
    rng = np.random.default_rng(q * 100 + 7)
    blocks = _int_blocks(rng, m * n)
    results = _chunk_results(chunked, blocks)
    pairs = _random_decodable_prefixes(chunked, rng)
    got = chunked.decode(pairs, results)
    for g, want in zip(got, blocks):
        g = g.toarray() if sp.issparse(g) else np.asarray(g)
        np.testing.assert_allclose(g, want, atol=1e-6,
                                   err_msg=f"{scheme} q={q}")


@pytest.mark.parametrize("q", [1, 2, 4])
def test_chunked_decode_bit_identical_exact_arithmetic(q):
    """Property: with integer blocks and unit weights (peel-only exact ops),
    chunked decode at FULL progress is bit-identical to the atomic decode --
    and any random decodable prefix subset recovers the exact same bits."""
    m, n, N = 2, 3, 24
    inst = get_scheme("lt_code").instance(m, n, N, seed=5)
    chunked = inst.chunked(q)
    rng = np.random.default_rng(11)
    blocks = _int_blocks(rng, m * n)
    results = _chunk_results(chunked, blocks)

    full_pairs = [(w, c) for w in range(N) for c in range(q)]
    atomic = inst.decode(list(range(N)),
                         {r: _chunk_results(inst.chunked(1), blocks)[r]
                          for r in range(N)})
    for pairs in (full_pairs, _random_decodable_prefixes(chunked, rng)):
        if not chunked.can_decode(pairs):
            continue  # lt peeling can stall on a random subset
        got = chunked.decode(pairs, results)
        for g, a, want in zip(got, atomic, blocks):
            np.testing.assert_array_equal(np.asarray(g), want)
            np.testing.assert_array_equal(np.asarray(a), want)
            np.testing.assert_array_equal(np.asarray(g), np.asarray(a))


@pytest.mark.parametrize("q", [2, 4])
def test_chunk_work_preserves_totals(q):
    """Equal total work: per-worker chunk work sums to the atomic cost."""
    for scheme in sorted(scheme_names()):
        sch = get_scheme(scheme)
        inst = (sch.instance(2, 2) if scheme == "uncoded"
                else sch.instance(2, 2, 10, seed=1))
        work = inst.chunked(q).chunk_work()
        assert work.shape == (inst.num_workers, q)
        np.testing.assert_allclose(work.sum(axis=1), inst.cost_factor,
                                   err_msg=scheme)
        assert (work >= 0).all()


# -------------------------- incremental rank tracker ------------------------

@pytest.mark.parametrize("d,K,seed", [(4, 10, 0), (9, 30, 1), (16, 50, 2)])
def test_incremental_rank_matches_oracle(d, K, seed):
    """Tracker rank == np.linalg.matrix_rank of the arrival prefix, at every
    arrival, across randomized arrival orders and dependent-row mixes."""
    rng = np.random.default_rng(seed)
    base = rng.integers(-3, 4, size=(K // 2, d)).astype(float)
    # mix in exact dependents: duplicates, scalings, sums, and zero rows
    dep = [base[rng.integers(len(base))] * rng.integers(-2, 3)
           for _ in range(K - len(base) - 2)]
    rows = np.vstack([base, np.zeros((2, d)), np.asarray(dep)])
    for trial in range(4):
        order = rng.permutation(len(rows))
        tracker = IncrementalRankTracker(d)
        for i, idx in enumerate(order):
            tracker.add(rows[idx])
            want = int(np.linalg.matrix_rank(rows[order[:i + 1]]))
            assert tracker.rank == want, (
                f"arrival {i}: tracker {tracker.rank} != oracle {want}")
        assert tracker.is_full == (np.linalg.matrix_rank(rows) >= d)


def test_incremental_rank_accepts_sparse_rows():
    M = sp.csr_matrix(np.array([[1.0, 0, 0], [0, 2.0, 0], [1.0, 2.0, 0],
                                [0, 0, 3.0]]))
    tracker = IncrementalRankTracker(3)
    assert tracker.add(M[0])
    assert tracker.add(M[1])
    assert not tracker.add(M[2])   # dependent
    assert tracker.add(M[3])
    assert tracker.is_full


# ------------------------------ runtime behavior ----------------------------

def test_chunked_sim_beats_atomic_under_slow_workers():
    """Acceptance: equal total work, SlowWorkers -- chunked completion time
    strictly below atomic (partial stragglers contribute their prefixes)."""
    from repro.core import schemes

    code = schemes.sparse_code(4, 4, 24, seed=1)
    rng0 = np.random.default_rng(0)
    blocks = _int_blocks(rng0, 16)
    strag = SlowWorkers(num_slow=6, slowdown=10.0)
    means = {}
    for q in (1, 2, 4):
        reps = [run_coded_job(code, blocks, strag,
                              rng=np.random.default_rng(100 + t),
                              unit_block_time=0.05, num_chunks=q)
                for t in range(5)]
        means[q] = float(np.mean([r.sim_compute_time for r in reps]))
    assert means[2] < means[1], means
    assert means[4] < means[1], means


@pytest.mark.parametrize("model", [SlowWorkerRates(num_slow=3, slowdown=8.0),
                                   LogNormalRates(sigma=0.7)])
def test_rate_models_chunk_times(model):
    """Rate models: cumulative chunk times, consistent with the legacy API."""
    rng = np.random.default_rng(4)
    work = np.abs(rng.random((12, 4))) + 0.01
    times = model.chunk_completion_times(work, np.random.default_rng(9))
    assert times.shape == work.shape
    assert (np.diff(times, axis=1) >= 0).all(), "chunk times must be ordered"
    # same rng seed => same rates => the last chunk lands at the legacy
    # completion_times of the total work
    legacy = model.completion_times(work.sum(axis=1), np.random.default_rng(9))
    np.testing.assert_allclose(times[:, -1], legacy)


def test_time_model_adapter_spreads_linearly():
    """Legacy completion-time models adapt to chunks by linear spreading."""
    work = np.array([[1.0, 1.0, 2.0], [2.0, 1.0, 1.0]])
    times = SlowWorkers(num_slow=0).chunk_completion_times(
        work, np.random.default_rng(0))
    np.testing.assert_allclose(times, [[0.25, 0.5, 1.0], [0.5, 0.75, 1.0]] *
                               work.sum(axis=1, keepdims=True))


def test_chunked_sim_decodes_exactly():
    from repro.core import schemes

    code = schemes.sparse_code(3, 2, 18, seed=6)
    rng = np.random.default_rng(2)
    blocks = _int_blocks(rng, 6)
    rep = run_coded_job(code, blocks, LogNormalRates(0.6),
                        rng=np.random.default_rng(8), num_chunks=3,
                        keep_blocks=True)
    assert rep.num_chunks == 3
    assert rep.chunks_used >= rep.workers_used
    for got, want in zip(rep.blocks, blocks):
        np.testing.assert_array_equal(np.asarray(got), want)


# ------------------------- device-path chunk masks --------------------------

def test_plan_with_chunk_progress_masks_prefix():
    from repro.core.coded_matmul import chunk_mask_progress, make_plan

    plan = make_plan(2, 2, num_workers=8, seed=5)
    q = 2
    progress = np.full(8, q)
    progress[3] = 1
    p2 = plan.with_chunk_progress(progress, q)
    # boundaries follow the worker's ACTUAL degree (host rule), not the
    # padded table width -- host-observed progress drives the device rebind
    deg3 = int(np.count_nonzero(plan.weights[3]))
    kept = chunk_slices(deg3, q)[0]
    np.testing.assert_array_equal(p2.weights[3, kept.stop:], 0.0)
    np.testing.assert_array_equal(p2.weights[3, :kept.stop],
                                  plan.weights[3, :kept.stop])
    assert 0 < kept.stop < deg3 or deg3 == 1
    # other workers untouched; decode re-derived for the masked system
    np.testing.assert_array_equal(p2.weights[:3], plan.weights[:3])
    M_eff = p2.coefficient_matrix()
    np.testing.assert_allclose(p2.decode @ M_eff, np.eye(4), atol=1e-4)

    # mask round-trip helper: prefix form ok, holes rejected
    mask = np.ones((8, q), dtype=bool)
    mask[3, 1] = False
    np.testing.assert_array_equal(chunk_mask_progress(mask, 8), progress)
    bad = mask.copy()
    bad[5] = [False, True]
    with pytest.raises(ValueError, match="prefix"):
        chunk_mask_progress(bad, 8)


def test_block_sparse_refuses_pack_without_slot_of():
    """A pack lacking the tile->slot map cannot follow chunk-masked weights;
    the factory must refuse it instead of silently using base weights."""
    import dataclasses

    from repro.core.coded_matmul import (
        _make_block_sparse_local_product, make_plan, pack_worker_tiles)
    from repro.sparse import dense_to_block_ell

    plan = make_plan(2, 2, num_workers=8, seed=0)
    rng = np.random.default_rng(0)
    ell = dense_to_block_ell(
        rng.standard_normal((32, 32)).astype(np.float32), block_size=8)
    pack = pack_worker_tiles(ell, plan)
    legacy = dataclasses.replace(pack, slot_of=None)
    with pytest.raises(ValueError, match="slot_of"):
        _make_block_sparse_local_product(plan, legacy, bt=8)
    assert _make_block_sparse_local_product(plan, pack, bt=8) is not None


def test_plan_chunk_progress_rank_loss_raises():
    from repro.core.decoder import DecodingError
    from repro.core.coded_matmul import make_plan

    plan = make_plan(2, 2, num_workers=8, seed=5)
    with pytest.raises(DecodingError):
        plan.with_chunk_progress(np.zeros(8, dtype=int), 2)
