import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import build
from repro.training import checkpoint as ckpt_lib
from repro.training.compress import coded_aggregate, error_feedback_update
from repro.training.data import SyntheticCorpus
from repro.training.optimizer import (
    AdamW,
    apply_updates,
    clip_by_global_norm,
    cosine_warmup_schedule,
    global_norm,
)
from repro.training.train_step import make_train_step


@pytest.fixture(scope="module")
def small_setup():
    cfg = configs.get("internlm2-1.8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    batch = {k: jnp.asarray(v)
             for k, v in SyntheticCorpus(cfg, 2, 16, seed=0).make_batch(0).items()}
    return cfg, model, params, batch


def test_train_loss_decreases(small_setup):
    cfg, model, params, batch = small_setup
    opt = AdamW(lr=1e-2)
    step = jax.jit(make_train_step(model, opt))
    opt_state = opt.init(params)
    losses = []
    for i in range(12):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_adamw_bf16_state(small_setup):
    cfg, model, params, batch = small_setup
    opt = AdamW(lr=1e-3, state_dtype=jnp.bfloat16)
    opt_state = opt.init(params)
    assert all(m.dtype == jnp.bfloat16 for m in jax.tree.leaves(opt_state["m"]))
    step = jax.jit(make_train_step(model, opt))
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert float(gn) > 1.0
    assert np.isclose(float(global_norm(clipped)), 1.0, atol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_warmup_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert np.isclose(float(lr(jnp.int32(10))), 1e-3, rtol=1e-5)
    assert float(lr(jnp.int32(100))) < 2e-4 + 1e-9


def test_checkpoint_roundtrip(tmp_path, small_setup):
    cfg, model, params, batch = small_setup
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    ckpt_lib.save_checkpoint(tmp_path, 7, params, opt_state)
    assert ckpt_lib.latest_step(tmp_path) == 7
    p2, o2, step = ckpt_lib.restore_checkpoint(tmp_path, params, opt_state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_coded_checkpoint_restores_with_losses(tmp_path, small_setup):
    cfg, model, params, _ = small_setup
    manifest = ckpt_lib.save_coded_checkpoint(tmp_path, 3, params, m=2, n=2,
                                              num_targets=10)
    # kill 3 of 10 storage targets; restore must still succeed
    available = [0, 2, 3, 5, 6, 8, 9]
    restored, stats = ckpt_lib.restore_coded_checkpoint(tmp_path, 3, params,
                                                        available=available)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
    assert stats.peels + stats.roots == 4


def test_coded_checkpoint_refuses_when_rank_lost(tmp_path, small_setup):
    cfg, model, params, _ = small_setup
    from repro.core.decoder import DecodingError
    ckpt_lib.save_coded_checkpoint(tmp_path, 4, params, m=2, n=2, num_targets=8)
    with pytest.raises((DecodingError, ValueError)):
        ckpt_lib.restore_coded_checkpoint(tmp_path, 4, params, available=[0])


def test_error_feedback_compression_converges():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    resid = None
    total_sent = jax.tree.map(jnp.zeros_like, g)
    for _ in range(30):
        sent, resid = error_feedback_update(g, resid, frac=0.1)
        total_sent = jax.tree.map(lambda t, s: t + s, total_sent, sent)
        nnz_frac = float(jnp.mean(sent["w"] != 0))
        assert nnz_frac <= 0.11
    # error feedback: cumulative transmitted mass approaches 30 * g
    ratio = float(jnp.linalg.norm(total_sent["w"]) / (30 * jnp.linalg.norm(g["w"])))
    assert ratio > 0.8


def test_coded_aggregate_exact_and_fault_tolerant():
    rng = np.random.default_rng(1)
    shards = [np.zeros(1000, np.float32) for _ in range(4)]
    for s in shards:  # sparse gradients
        idx = rng.choice(1000, size=50, replace=False)
        s[idx] = rng.standard_normal(50)
    want = np.sum(shards, axis=0)
    got, stats = coded_aggregate(shards, m=2, n=2, num_workers=8)
    np.testing.assert_allclose(got, want, atol=1e-5)
    # kill two aggregators
    got2, _ = coded_aggregate(shards, m=2, n=2, num_workers=8,
                              survivors=[0, 1, 3, 4, 6, 7])
    np.testing.assert_allclose(got2, want, atol=1e-5)
