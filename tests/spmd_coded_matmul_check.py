"""Standalone SPMD check for coded_matmul, run by tests in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
keeps the default single device per the project's dry-run isolation rule)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.coded_matmul import coded_matmul, make_plan, uncoded_matmul_reference


def main():
    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(0)
    for (m, n) in [(2, 2), (2, 3), (4, 2)]:
        plan = make_plan(m, n, num_workers=8, seed=5)
        s, r, t = 32, 8 * m, 12 * n
        A = jnp.asarray(rng.standard_normal((s, r)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
        C = coded_matmul(A, B, plan, mesh)
        C_ref = uncoded_matmul_reference(A, B)
        np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref),
                                   atol=5e-2, rtol=1e-3)
        print(f"coded_matmul ok m={m} n={n}")

        # fault tolerance: kill one worker, decode from survivors
        M = np.zeros((8, m * n))
        for k in range(8):
            for l in range(plan.max_degree):
                if plan.weights[k, l] != 0:
                    M[k, plan.cols[k, l]] += plan.weights[k, l]
        for kill in range(8):
            surv = np.ones(8, dtype=bool)
            surv[kill] = False
            if np.linalg.matrix_rank(M * surv[:, None]) < m * n:
                continue
            C2 = coded_matmul(A, B, plan, mesh, survivors=surv)
            np.testing.assert_allclose(np.asarray(C2), np.asarray(C_ref),
                                       atol=5e-2, rtol=1e-3)
            print(f"  survivor decode ok (killed worker {kill})")
            break
    print("ALL-OK")


if __name__ == "__main__":
    main()
