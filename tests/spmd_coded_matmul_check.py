"""Standalone SPMD check for the coded-matmul op, run by tests in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main
pytest process keeps the default single device per the project's dry-run
isolation rule).

Covers the ``repro.coded`` CodedOp across both local-compute backends
(dense_scan and the block-sparse fused-gather path) against the uncoded
reference, with and without straggler masks; the scatter decode
(out_sharded=True) against the replicated decode; a jaxpr inspection
proving the block_sparse path never materializes a (max_degree * s)-row
stacked operand (the old B_tall gather); and the API-redesign acceptance
matrix -- the new ``CodedOp.apply`` must be BIT-identical to the legacy
``coded_matmul(...)`` shim for both backends x {all-alive, 1-dead, 2-dead}
x {replicated, out_sharded} on the 8-device mesh.  The chunked protocol
adds a partial-survivor axis: (N, q) per-chunk masks where a device that
completed only its first chunks contributes those slots to the decode
(``check_partial_chunk_survivors``), with the same old/new bit-parity."""

import os
import warnings

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro import compat
from repro.analysis.jaxpr_check import (
    assert_detector_sensitivity,
    stacked_intermediates,
)
from repro.coded import CodedMatmulConfig, from_plan
from repro.core.coded_matmul import (
    chunk_mask_progress,
    coded_matmul,
    make_plan,
    uncoded_matmul_reference,
)
from repro.sparse import dense_to_block_ell


def _op(plan, mesh, backend, out_sharded=False):
    cfg = CodedMatmulConfig(backend=backend, out_sharded=out_sharded)
    return from_plan(cfg, plan).bind(mesh)


def _kill_masks(plan, n_dead_options=(1, 2)):
    """One survivor mask per dead-count that keeps the code decodable."""
    M = plan.coefficient_matrix()
    d = plan.m * plan.n
    rng = np.random.default_rng(0)
    masks = []
    for n_dead in n_dead_options:
        for _ in range(200):
            surv = np.ones(plan.num_workers, dtype=bool)
            surv[rng.choice(plan.num_workers, size=n_dead, replace=False)] = False
            if np.linalg.matrix_rank(M * surv[:, None]) >= d:
                masks.append(surv)
                break
    return masks


def check_no_stacked_intermediate(A, B, plan, mesh, ell, s):
    """The nnz-proportional claim, enforced on the trace: no gather/reshape
    in the block_sparse program may produce an array with a max_degree * s
    dimension (the old stacked B_tall / stacked-operand row count).

    The detector itself lives in ``repro.analysis.jaxpr_check`` (shared with
    the ``python -m repro.analysis`` CI gate); this check exercises the same
    pass on this plan's staged program, plus the pass's own sensitivity
    probe against the legacy stacked gather."""
    op = _op(plan, mesh, "block_sparse")
    closed = jax.make_jaxpr(lambda a, b: op.apply(a, b, a_sparse=ell))(A, B)
    stacked = plan.max_degree * s
    offenders = stacked_intermediates(closed.jaxpr, stacked)
    assert not offenders, (
        f"block_sparse path materializes a {stacked}-row intermediate "
        f"(max_degree={plan.max_degree} * s={s}): {offenders}")
    # detector sensitivity: the OLD B_tall gather/transpose/reshape must trip
    _, t = B.shape
    assert_detector_sensitivity(plan.max_degree, s, plan.n, t // plan.n)


def _chunk_masks(plan, q=2, want=1):
    """(N, q) prefix-form per-chunk masks that keep the code decodable,
    each with at least one PARTIAL worker (0 < progress < q)."""
    rng = np.random.default_rng(1)
    N, d = plan.num_workers, plan.m * plan.n
    masks = []
    for _ in range(500):
        progress = np.full(N, q)
        idx = rng.choice(N, size=2, replace=False)
        progress[idx] = rng.integers(0, q, size=2)
        if not ((progress > 0) & (progress < q)).any():
            continue
        try:
            plan.with_chunk_progress(progress, q)
        except ValueError:
            continue
        mask = np.zeros((N, q), dtype=bool)
        for k, p in enumerate(progress):
            mask[k, :p] = True
        masks.append(mask)
        if len(masks) == want:
            break
    assert masks, "no decodable partial chunk mask found for this plan"
    return masks


def check_partial_chunk_survivors(A, B, plan, mesh, ell, C_ref):
    """The chunked-protocol acceptance axis: a device that completed only
    its first chunks contributes those slots to the decode (per-chunk
    survivor mask), on every backend x decode layout, bit-identical
    between the op API and the legacy shim."""
    for mask in _chunk_masks(plan, q=2):
        progress = chunk_mask_progress(mask, plan.num_workers)
        tag = f"progress={progress.tolist()}"
        for backend in ("dense_scan", "block_sparse"):
            kw = {"a_sparse": ell} if backend == "block_sparse" else {}
            for out_sharded in (False, True):
                op = _op(plan, mesh, backend, out_sharded).with_survivors(mask)
                C = op.apply(A, B, **kw)
                np.testing.assert_allclose(
                    np.asarray(C), np.asarray(C_ref), atol=5e-2, rtol=1e-3,
                    err_msg=f"partial-chunk decode ({backend}, {tag})")
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", DeprecationWarning)
                    C_old = coded_matmul(
                        A, B, plan, mesh, survivors=mask, backend=backend,
                        out_sharded=out_sharded, **kw)
                assert np.array_equal(np.asarray(C), np.asarray(C_old)), (
                    f"per-chunk mask: new API != legacy ({backend}, {tag}, "
                    f"out_sharded={out_sharded})")
                print(f"  partial-chunk survivors ok ({backend}, {tag}, "
                      f"out_sharded={out_sharded})")


def check_scatter_decode(A, B, plan, mesh, ell, C_ref):
    """psum_scatter decode must agree with the replicated psum decode --
    bit-for-bit on every backend, with and without a dead worker."""
    masks = [None] + _kill_masks(plan, (1,))
    for surv in masks:
        tag = "all-alive" if surv is None else f"killed {int(np.flatnonzero(~surv)[0])}"
        for backend in ("dense_scan", "block_sparse"):
            kw = {"a_sparse": ell} if backend == "block_sparse" else {}
            op_rep = _op(plan, mesh, backend)
            op_sc = _op(plan, mesh, backend, out_sharded=True)
            if surv is not None:
                op_rep = op_rep.with_survivors(surv)
                op_sc = op_sc.with_survivors(surv)
            C_rep = op_rep.apply(A, B, **kw)
            C_sc = op_sc.apply(A, B, **kw)
            assert np.array_equal(np.asarray(C_sc), np.asarray(C_rep)), (
                f"scatter decode != replicated decode ({backend}, {tag})")
            np.testing.assert_allclose(np.asarray(C_sc), np.asarray(C_ref),
                                       atol=5e-2, rtol=1e-3)
            print(f"  scatter decode ok ({backend}, {tag})")


def check_old_new_parity(A, B, plan, mesh, ell):
    """Acceptance matrix: CodedOp.apply bit-identical to legacy coded_matmul
    for backends x {all-alive, 1-dead, 2-dead} x {replicated, scattered}.

    The dead-worker axis only exists where the code can spare workers: a
    plan with N - k < mn has no decodable k-dead mask at all (rank < mn is
    certain), so the full 3-mask matrix is required exactly when
    N - 2 > mn (e.g. the 2x2 plan on 8 devices)."""
    masks = [None] + _kill_masks(plan, (1, 2))
    if plan.num_workers - 2 > plan.m * plan.n:
        assert len(masks) == 3, "no decodable 1- and 2-dead masks for this plan"
    for surv in masks:
        n_dead = 0 if surv is None else int((~surv).sum())
        for backend in ("dense_scan", "block_sparse"):
            kw = {"a_sparse": ell} if backend == "block_sparse" else {}
            for out_sharded in (False, True):
                op = _op(plan, mesh, backend, out_sharded)
                if surv is not None:
                    op = op.with_survivors(surv)
                C_new = op.apply(A, B, **kw)
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", DeprecationWarning)
                    C_old = coded_matmul(
                        A, B, plan, mesh, survivors=surv, backend=backend,
                        out_sharded=out_sharded, **kw)
                assert np.array_equal(np.asarray(C_new), np.asarray(C_old)), (
                    f"new API != legacy ({backend}, dead={n_dead}, "
                    f"out_sharded={out_sharded})")
                print(f"  old/new parity ok ({backend}, dead={n_dead}, "
                      f"out_sharded={out_sharded})")


def check_fused_vs_two_step_schemes(mesh):
    """One-launch acceptance matrix: the fused decode epilogue staged for
    block_sparse must be BIT-identical (f32) to the legacy two-step decode
    (local product, then the separate ``D @ C~`` combine) for every
    registered scheme x {0, 1, 2 dead workers} x decode layout.  The
    two-step reference is produced by the SAME op with the backend entry's
    ``fused_decode`` flag toggled off -- everything else (plan, pack,
    survivor mask, psum) identical."""
    import dataclasses as _dc

    from repro.coded import get_scheme, scheme_names
    from repro.core import coded_backends

    rng = np.random.default_rng(3)
    m, n = 2, 2
    s, r, t = 32, 8 * m, 12 * n
    mask = rng.random((s // 8, r // 8)) < 0.5
    A = jnp.asarray(rng.standard_normal((s, r))
                    * np.kron(mask, np.ones((8, 8))), jnp.float32)
    B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
    ell = dense_to_block_ell(np.asarray(A, np.float32), block_size=8)
    entry = coded_backends.get_backend("block_sparse")
    for name in sorted(scheme_names()):
        sch = get_scheme(name)
        if name != "uncoded" and not sch.device_capable(m, n, 8):
            continue
        if name == "uncoded":
            plan = sch.plan(m, n, None, seed=2)  # N == mn == 4
            use_mesh = compat.make_mesh((4,), ("model",),
                                        devices=jax.devices()[:4])
            masks = [None]  # uncoded tolerates no dead workers
        else:
            plan = sch.plan(m, n, 8, seed=2)
            use_mesh = mesh
            masks = [None] + _kill_masks(plan, (1, 2))
        for surv in masks:
            n_dead = 0 if surv is None else int((~surv).sum())
            for out_sharded in (False, True):
                op = _op(plan, use_mesh, "block_sparse", out_sharded)
                if surv is not None:
                    op = op.with_survivors(surv)
                C_fused = op.apply(A, B, a_sparse=ell)
                entry.fused_decode = False
                try:
                    C_two = op.apply(A, B, a_sparse=ell)
                finally:
                    entry.fused_decode = True
                assert np.array_equal(np.asarray(C_fused), np.asarray(C_two)), (
                    f"fused epilogue != two-step decode (scheme={name}, "
                    f"dead={n_dead}, out_sharded={out_sharded})")
            print(f"  fused==two-step ok (scheme={name}, dead={n_dead})")


def main():
    assert len(jax.devices()) == 8
    mesh = compat.make_mesh((8,), ("model",))
    rng = np.random.default_rng(0)
    for (m, n) in [(2, 2), (2, 3), (4, 2)]:
        plan = make_plan(m, n, num_workers=8, seed=5)
        s, r, t = 32, 8 * m, 12 * n
        A = rng.standard_normal((s, r))
        # zero ~half the 8x8 tiles so the block-sparse backend has real
        # structure to exploit (and the dense reference still agrees)
        mask = rng.random((s // 8, r // 8)) < 0.5
        A = jnp.asarray(A * np.kron(mask, np.ones((8, 8))), jnp.float32)
        B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
        C_ref = uncoded_matmul_reference(A, B)
        ell = dense_to_block_ell(np.asarray(A, np.float32), block_size=8)
        for backend in ("dense_scan", "block_sparse"):
            kw = {"a_sparse": ell} if backend == "block_sparse" else {}
            C = _op(plan, mesh, backend).apply(A, B, **kw)
            np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref),
                                       atol=5e-2, rtol=1e-3)
            print(f"coded op ok m={m} n={n} backend={backend}")

        check_no_stacked_intermediate(A, B, plan, mesh, ell, s)
        print(f"  no stacked (max_degree*s) intermediate (m={m} n={n})")
        check_scatter_decode(A, B, plan, mesh, ell, C_ref)
        check_old_new_parity(A, B, plan, mesh, ell)
        check_partial_chunk_survivors(A, B, plan, mesh, ell, C_ref)

        # fault tolerance: kill one worker, rebind, decode from survivors --
        # on both backends (the decode re-derivation is backend-independent,
        # but the masked psum must agree on-device either way)
        for surv in _kill_masks(plan, (1,)):
            kill = int(np.flatnonzero(~surv)[0])
            for backend in ("dense_scan", "block_sparse"):
                kw = {"a_sparse": ell} if backend == "block_sparse" else {}
                C2 = _op(plan, mesh, backend).with_survivors(surv).apply(A, B, **kw)
                np.testing.assert_allclose(np.asarray(C2), np.asarray(C_ref),
                                           atol=5e-2, rtol=1e-3)
                print(f"  survivor decode ok (killed worker {kill}, {backend})")
    check_fused_vs_two_step_schemes(mesh)
    print("ALL-OK")


if __name__ == "__main__":
    main()
