"""Standalone SPMD check for coded_matmul, run by tests in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
keeps the default single device per the project's dry-run isolation rule).

Covers both local-compute backends (dense_scan and the block-sparse Pallas
path) against the uncoded reference, with and without a straggler mask."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro import compat
from repro.core.coded_matmul import coded_matmul, make_plan, uncoded_matmul_reference


def main():
    assert len(jax.devices()) == 8
    mesh = compat.make_mesh((8,), ("model",))
    rng = np.random.default_rng(0)
    for (m, n) in [(2, 2), (2, 3), (4, 2)]:
        plan = make_plan(m, n, num_workers=8, seed=5)
        s, r, t = 32, 8 * m, 12 * n
        A = rng.standard_normal((s, r))
        # zero ~half the 8x8 tiles so the block-sparse backend has real
        # structure to exploit (and the dense reference still agrees)
        mask = rng.random((s // 8, r // 8)) < 0.5
        A = jnp.asarray(A * np.kron(mask, np.ones((8, 8))), jnp.float32)
        B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
        C_ref = uncoded_matmul_reference(A, B)
        for backend in ("dense_scan", "block_sparse"):
            C = coded_matmul(A, B, plan, mesh, backend=backend)
            np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref),
                                       atol=5e-2, rtol=1e-3)
            print(f"coded_matmul ok m={m} n={n} backend={backend}")

        # fault tolerance: kill one worker, decode from survivors -- on both
        # backends (the decode re-derivation is backend-independent, but the
        # masked psum must agree on-device either way)
        M = plan.coefficient_matrix()
        for kill in range(8):
            surv = np.ones(8, dtype=bool)
            surv[kill] = False
            if np.linalg.matrix_rank(M * surv[:, None]) < m * n:
                continue
            for backend in ("dense_scan", "block_sparse"):
                C2 = coded_matmul(A, B, plan, mesh, survivors=surv,
                                  backend=backend)
                np.testing.assert_allclose(np.asarray(C2), np.asarray(C_ref),
                                           atol=5e-2, rtol=1e-3)
                print(f"  survivor decode ok (killed worker {kill}, {backend})")
            break
    print("ALL-OK")


if __name__ == "__main__":
    main()
