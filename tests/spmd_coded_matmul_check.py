"""Standalone SPMD check for coded_matmul, run by tests in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest process
keeps the default single device per the project's dry-run isolation rule).

Covers both local-compute backends (dense_scan and the block-sparse
fused-gather path) against the uncoded reference, with and without a
straggler mask; the scatter decode (out_sharded=True) against the
replicated decode, with and without a dead worker; and a jaxpr inspection
proving the block_sparse path never materializes a (max_degree * s)-row
stacked operand (the old B_tall gather)."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro import compat
from repro.core.coded_matmul import coded_matmul, make_plan, uncoded_matmul_reference
from repro.sparse import dense_to_block_ell


def _walk_avals(jaxpr):
    """Every output aval of every equation, descending into sub-jaxprs."""
    from jax.core import ClosedJaxpr, Jaxpr

    def subs(val):
        if isinstance(val, ClosedJaxpr):
            yield val.jaxpr
        elif isinstance(val, Jaxpr):
            yield val
        elif isinstance(val, (list, tuple)):
            for v in val:
                yield from subs(v)

    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            yield eqn.primitive.name, v.aval
        for param in eqn.params.values():
            for sub in subs(param):
                yield from _walk_avals(sub)


def check_no_stacked_intermediate(A, B, plan, mesh, ell, s):
    """The nnz-proportional claim, enforced on the trace: no gather/reshape
    in the block_sparse program may produce an array with a max_degree * s
    dimension (the old stacked B_tall / stacked-operand row count)."""
    closed = jax.make_jaxpr(lambda a, b: coded_matmul(
        a, b, plan, mesh, backend="block_sparse", a_sparse=ell))(A, B)
    stacked = plan.max_degree * s
    offenders = [
        (prim, tuple(aval.shape))
        for prim, aval in _walk_avals(closed.jaxpr)
        if getattr(aval, "shape", ()) and aval.shape[0] == stacked
    ]
    assert not offenders, (
        f"block_sparse path materializes a {stacked}-row intermediate "
        f"(max_degree={plan.max_degree} * s={s}): {offenders}")
    # detector sensitivity: the OLD B_tall gather/transpose/reshape must trip
    L, (_, t) = plan.max_degree, B.shape
    n, bt = plan.n, t // plan.n

    def old_stack(b):
        bsel = jnp.take(b.reshape(s, n, bt), jnp.zeros((L,), jnp.int32), axis=1)
        return bsel.transpose(1, 0, 2).reshape(L * s, bt)

    tripped = [
        aval for _, aval in _walk_avals(jax.make_jaxpr(old_stack)(B).jaxpr)
        if getattr(aval, "shape", ()) and aval.shape[0] == stacked
    ]
    assert tripped, "jaxpr walker failed to flag the legacy stacked gather"


def check_scatter_decode(A, B, plan, mesh, ell, C_ref):
    """psum_scatter decode must agree with the replicated psum decode --
    bit-for-bit on every backend, with and without a dead worker."""
    masks = [None]
    M = plan.coefficient_matrix()
    for kill in range(plan.num_workers):
        surv = np.ones(plan.num_workers, dtype=bool)
        surv[kill] = False
        if np.linalg.matrix_rank(M * surv[:, None]) >= plan.m * plan.n:
            masks.append(surv)
            break
    for surv in masks:
        tag = "all-alive" if surv is None else f"killed {int(np.flatnonzero(~surv)[0])}"
        for backend in ("dense_scan", "block_sparse"):
            kw = {"a_sparse": ell} if backend == "block_sparse" else {}
            C_rep = coded_matmul(A, B, plan, mesh, survivors=surv,
                                 backend=backend, **kw)
            C_sc = coded_matmul(A, B, plan, mesh, survivors=surv,
                                backend=backend, out_sharded=True, **kw)
            assert np.array_equal(np.asarray(C_sc), np.asarray(C_rep)), (
                f"scatter decode != replicated decode ({backend}, {tag})")
            np.testing.assert_allclose(np.asarray(C_sc), np.asarray(C_ref),
                                       atol=5e-2, rtol=1e-3)
            print(f"  scatter decode ok ({backend}, {tag})")


def main():
    assert len(jax.devices()) == 8
    mesh = compat.make_mesh((8,), ("model",))
    rng = np.random.default_rng(0)
    for (m, n) in [(2, 2), (2, 3), (4, 2)]:
        plan = make_plan(m, n, num_workers=8, seed=5)
        s, r, t = 32, 8 * m, 12 * n
        A = rng.standard_normal((s, r))
        # zero ~half the 8x8 tiles so the block-sparse backend has real
        # structure to exploit (and the dense reference still agrees)
        mask = rng.random((s // 8, r // 8)) < 0.5
        A = jnp.asarray(A * np.kron(mask, np.ones((8, 8))), jnp.float32)
        B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
        C_ref = uncoded_matmul_reference(A, B)
        for backend in ("dense_scan", "block_sparse"):
            C = coded_matmul(A, B, plan, mesh, backend=backend)
            np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref),
                                       atol=5e-2, rtol=1e-3)
            print(f"coded_matmul ok m={m} n={n} backend={backend}")

        ell = dense_to_block_ell(np.asarray(A, np.float32), block_size=8)
        check_no_stacked_intermediate(A, B, plan, mesh, ell, s)
        print(f"  no stacked (max_degree*s) intermediate (m={m} n={n})")
        check_scatter_decode(A, B, plan, mesh, ell, C_ref)

        # fault tolerance: kill one worker, decode from survivors -- on both
        # backends (the decode re-derivation is backend-independent, but the
        # masked psum must agree on-device either way)
        M = plan.coefficient_matrix()
        for kill in range(8):
            surv = np.ones(8, dtype=bool)
            surv[kill] = False
            if np.linalg.matrix_rank(M * surv[:, None]) < m * n:
                continue
            for backend in ("dense_scan", "block_sparse"):
                C2 = coded_matmul(A, B, plan, mesh, survivors=surv,
                                  backend=backend)
                np.testing.assert_allclose(np.asarray(C2), np.asarray(C_ref),
                                           atol=5e-2, rtol=1e-3)
                print(f"  survivor decode ok (killed worker {kill}, {backend})")
            break
    print("ALL-OK")


if __name__ == "__main__":
    main()
