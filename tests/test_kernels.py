"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles,
plus hypothesis property sweeps.  Kernels run in interpret mode on CPU.

hypothesis is an optional test dependency (requirements-test.txt): without
it the property sweeps skip but collection -- and the deterministic sweeps
-- still run (so `pytest -x` never hard-fails on the import)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
except ModuleNotFoundError:  # property sweeps skip; see module docstring
    given = settings = st = HealthCheck = None

from repro.kernels import ops
from repro.kernels.ref import coded_accum_ref, spmm_block_fused_ref, spmm_block_ref
from repro.sparse import BlockELL, block_ell_to_dense, dense_to_block_ell

if given is not None:
    SETTINGS = dict(max_examples=10, deadline=None,
                    suppress_health_check=[HealthCheck.too_slow])


# ----------------------------- coded_accum --------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,s,r,t,L", [
    (2, 2, 128, 16, 24, 3),
    (2, 2, 256, 32, 32, 5),
    (4, 2, 128, 32, 16, 7),
    (1, 4, 128, 8, 32, 2),
    (3, 3, 384, 24, 36, 4),
])
def test_coded_accum_sweep(dtype, m, n, s, r, t, L):
    rng = np.random.default_rng(hash((m, n, s, r, t, L)) % 2**31)
    A = jnp.asarray(rng.standard_normal((s, r)), dtype)
    B = jnp.asarray(rng.standard_normal((s, t)), dtype)
    cols = jnp.asarray(rng.integers(0, m * n, size=L), jnp.int32)
    w = rng.standard_normal(L).astype(np.float32)
    w[-1] = 0.0  # exercise padding semantics
    w = jnp.asarray(w)
    got = ops.coded_accum(A, B, cols, w, m=m, n=n, s_chunk=128)
    want = coded_accum_ref(A, B, cols, w, m=m, n=n)
    atol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=1e-2)


if given is not None:
    @given(data=st.data())
    @settings(**SETTINGS)
    def test_coded_accum_property(data):
        m = data.draw(st.integers(1, 3))
        n = data.draw(st.integers(1, 3))
        L = data.draw(st.integers(1, 6))
        s = 128 * data.draw(st.integers(1, 2))
        br = 8 * data.draw(st.integers(1, 3))
        bt = 8 * data.draw(st.integers(1, 3))
        seed = data.draw(st.integers(0, 10_000))
        rng = np.random.default_rng(seed)
        A = jnp.asarray(rng.standard_normal((s, m * br)), jnp.float32)
        B = jnp.asarray(rng.standard_normal((s, n * bt)), jnp.float32)
        cols = jnp.asarray(rng.integers(0, m * n, size=L), jnp.int32)
        w = jnp.asarray(rng.standard_normal(L), jnp.float32)
        got = ops.coded_accum(A, B, cols, w, m=m, n=n)
        want = coded_accum_ref(A, B, cols, w, m=m, n=n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, rtol=1e-3)


# ----------------------------- spmm_block ---------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bs,RB,CB,t,density", [
    (8, 4, 4, 128, 0.3),
    (8, 8, 2, 256, 0.1),
    (16, 4, 4, 128, 0.5),
    (8, 2, 8, 128, 0.9),
])
def test_spmm_block_sweep(dtype, bs, RB, CB, t, density):
    rng = np.random.default_rng(hash((bs, RB, CB, t)) % 2**31)
    # build a block-sparse A directly
    mask = rng.random((RB, CB)) < density
    A = rng.standard_normal((RB * bs, CB * bs)) * np.kron(mask, np.ones((bs, bs)))
    ell = dense_to_block_ell(A, block_size=bs)
    B = jnp.asarray(rng.standard_normal((RB * bs, t)), dtype)
    vals = jnp.asarray(ell.vals, dtype)
    idx = jnp.asarray(ell.idx)
    got = ops.spmm_block(vals, idx, B, t_tile=128)
    want = spmm_block_ref(vals, idx, B, out_rows=CB * bs)
    atol = 2e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=atol, rtol=1e-2)
    # and against the dense oracle via the format round-trip
    dense = block_ell_to_dense(ell)
    want_dense = dense.T @ np.asarray(B, np.float64)
    np.testing.assert_allclose(np.asarray(got), want_dense,
                               atol=atol * 10, rtol=5e-2)


if given is not None:
    @given(data=st.data())
    @settings(**SETTINGS)
    def test_spmm_block_property(data):
        bs = data.draw(st.sampled_from([8, 16]))
        RB = data.draw(st.integers(1, 4))
        CB = data.draw(st.integers(1, 4))
        t = 128
        density = data.draw(st.floats(0.0, 1.0))
        seed = data.draw(st.integers(0, 10_000))
        rng = np.random.default_rng(seed)
        mask = rng.random((RB, CB)) < density
        A = rng.standard_normal((RB * bs, CB * bs)) * np.kron(mask, np.ones((bs, bs)))
        ell = dense_to_block_ell(A, block_size=bs)
        B = jnp.asarray(rng.standard_normal((RB * bs, t)), jnp.float32)
        got = ops.spmm_block(jnp.asarray(ell.vals, jnp.float32), jnp.asarray(ell.idx), B)
        want = np.asarray(block_ell_to_dense(ell)).T @ np.asarray(B)
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-3, rtol=1e-3)


def test_spmm_block_auto_interpret_matches_ref_on_cpu():
    """interpret=None auto-selects from the backend: off-TPU (this CPU
    container) the kernel must run interpreted and match the jnp oracle."""
    from repro.kernels.spmm_block import resolve_interpret

    assert jax.default_backend() != "tpu"
    assert resolve_interpret() is True
    assert resolve_interpret(False) is False  # explicit arg still wins
    rng = np.random.default_rng(7)
    bs, RB, CB, t = 8, 4, 3, 128
    mask = rng.random((RB, CB)) < 0.4
    A = rng.standard_normal((RB * bs, CB * bs)) * np.kron(mask, np.ones((bs, bs)))
    ell = dense_to_block_ell(A, block_size=bs)
    B = jnp.asarray(rng.standard_normal((RB * bs, t)), jnp.float32)
    vals = jnp.asarray(ell.vals, jnp.float32)
    idx = jnp.asarray(ell.idx)
    got = ops.spmm_block(vals, idx, B)          # interpret unspecified
    want = spmm_block_ref(vals, idx, B, out_rows=CB * bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# --------------------------- spmm_block_fused ------------------------------

def _random_fused_operands(rng, bs, CB, L, s, n, bt, zero_slots=1):
    vals = rng.standard_normal((CB, L, bs, bs)).astype(np.float32)
    src = np.stack([rng.integers(0, s // bs, (CB, L)),
                    rng.integers(0, n, (CB, L))], axis=-1).astype(np.int32)
    w = rng.standard_normal((CB, L)).astype(np.float32)
    if zero_slots:  # exercise padded-slot semantics: weight 0 kills the tile
        w[:, -zero_slots:] = 0.0
    B = rng.standard_normal((s, n * bt)).astype(np.float32)
    return vals, src, w, B


@pytest.mark.parametrize("bs", [8, 16])
@pytest.mark.parametrize("CB,L,s,n,bt", [
    (4, 3, 64, 2, 128),    # degree-ish L small, t_tile == bt
    (2, 7, 32, 3, 24),     # ragged bt (t_tile == 24), higher degree
    (3, 1, 48, 1, 32),     # single slot, single column group
])
def test_spmm_block_fused_sweep(bs, CB, L, s, n, bt):
    rng = np.random.default_rng(hash((bs, CB, L, s, n, bt)) % 2**31)
    vals, src, w, B = _random_fused_operands(rng, bs, CB, L, s, n, bt)
    want = spmm_block_fused_ref(jnp.asarray(vals), jnp.asarray(src),
                                jnp.asarray(w), jnp.asarray(B), bt)
    # dense einsum oracle: scatter the pack back to a dense stacked product
    dense_want = np.zeros((CB * bs, bt), np.float32)
    B4 = B.reshape(s // bs, bs, n, bt)
    for cb in range(CB):
        for l in range(L):
            brows = B4[src[cb, l, 0], :, src[cb, l, 1], :]
            dense_want[cb * bs:(cb + 1) * bs] += w[cb, l] * np.einsum(
                "io,it->ot", vals[cb, l], brows)
    np.testing.assert_allclose(np.asarray(want), dense_want, atol=1e-4, rtol=1e-3)
    # XLA gather path (the off-TPU default)
    got = ops.spmm_block_fused(jnp.asarray(vals), jnp.asarray(src),
                               jnp.asarray(w), jnp.asarray(B), bt=bt)
    np.testing.assert_allclose(np.asarray(got), dense_want, atol=1e-4, rtol=1e-3)
    # Pallas kernel body (interpreter), including the scalar-prefetched
    # weight and the two-level (row-block, column-group) index map
    got_pl = ops.spmm_block_fused(jnp.asarray(vals), jnp.asarray(src),
                                  jnp.asarray(w), jnp.asarray(B), bt=bt,
                                  t_tile=bt, interpret=True)
    np.testing.assert_allclose(np.asarray(got_pl), dense_want,
                               atol=1e-4, rtol=1e-3)


def test_spmm_block_fused_matches_packed_coded_product():
    """End-to-end over a real pack: the fused kernel on pack_worker_tiles
    output equals the worker's coded combination sum_l w_l A_{i_l}^T B_{j_l}
    computed densely, across every worker and degree the plan sampled."""
    from repro.core.coded_matmul import make_plan, pack_worker_tiles

    rng = np.random.default_rng(11)
    plan = make_plan(2, 2, num_workers=8, seed=1)
    s, r, t, bs = 32, 32, 24, 8
    m, n = 2, 2
    br, bt = r // m, t // n
    mask = rng.random((s // bs, r // bs)) < 0.6
    A = rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
    B = rng.standard_normal((s, t)).astype(np.float32)
    ell = dense_to_block_ell(A.astype(np.float32), block_size=bs)
    pack = pack_worker_tiles(ell, plan)
    for k in range(plan.num_workers):
        got = ops.spmm_block_fused(
            jnp.asarray(pack.vals[k]), jnp.asarray(pack.src[k]),
            jnp.asarray(pack.wslot[k]), jnp.asarray(B), bt=bt)
        want = np.zeros((br, bt), np.float32)
        for l in range(plan.max_degree):
            wgt = plan.weights[k, l]
            if wgt == 0.0:
                continue
            i, j = divmod(int(plan.cols[k, l]), n)
            want += wgt * (A[:, i * br:(i + 1) * br].T
                           @ B[:, j * bt:(j + 1) * bt])
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-3, rtol=1e-3)


# ----------------------- spmm_block_fused_decode ---------------------------
#
# The one-launch kernel: decode combine folded into the epilogue.  Parity is
# defined PER LANE -- the fused kernel must be bit-identical to the two-step
# composition (same-lane local product, then dvec[:, None, None] * C~[None])
# because both run the identical accumulation order; across lanes only
# allclose holds (einsum vs sequential slot accumulation reassociate).

LANES = ["xla", "tpu", "triton"]


def _fused_decode_case(seed=0, bs=8, CB=4, L=3, s=64, n=2, bt=128, mn=4):
    rng = np.random.default_rng(seed)
    vals, src, w, B = _random_fused_operands(rng, bs, CB, L, s, n, bt)
    dvec = rng.standard_normal(mn).astype(np.float32)
    return (jnp.asarray(vals), jnp.asarray(src), jnp.asarray(w),
            jnp.asarray(dvec), jnp.asarray(B))


@pytest.mark.parametrize("lane", LANES)
def test_fused_decode_bitwise_vs_two_step_per_lane(lane):
    vals, src, w, dvec, B = _fused_decode_case()
    # the tpu lane's two-step reference must run the SAME Pallas kernel
    # body (interpreted on this CPU box), not the XLA fallback the internal
    # policy would pick off-TPU -- bitwise parity is per accumulation order
    Ct = ops.spmm_block_fused(vals, src, w, B, bt=128, lane=lane,
                              interpret=True if lane == "tpu" else None)
    want = np.asarray(dvec)[:, None, None] * np.asarray(Ct)[None]
    got = ops.spmm_block_fused_decode(vals, src, w, dvec, B, bt=128, lane=lane)
    assert got.shape == (len(dvec), Ct.shape[0], Ct.shape[1])
    np.testing.assert_array_equal(np.asarray(got), want,
                                  err_msg=f"lane={lane} fused != two-step")


@pytest.mark.parametrize("lane", LANES)
def test_fused_decode_lanes_agree_allclose(lane):
    vals, src, w, dvec, B = _fused_decode_case(seed=5)
    ref = ops.spmm_block_fused_decode(vals, src, w, dvec, B, bt=128, lane="xla")
    got = ops.spmm_block_fused_decode(vals, src, w, dvec, B, bt=128, lane=lane)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("bt,t_tile", [(24, 24), (40, 8)])
def test_fused_decode_non_multiple_t_tile_shapes(bt, t_tile):
    # bt not a multiple of 128: the tpu lane must still tile correctly
    vals, src, w, dvec, B = _fused_decode_case(seed=9, s=32, n=3, bt=bt)
    ref = ops.spmm_block_fused_decode(vals, src, w, dvec, B, bt=bt, lane="xla")
    got = ops.spmm_block_fused_decode(vals, src, w, dvec, B, bt=bt,
                                      t_tile=t_tile, lane="tpu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    got_tr = ops.spmm_block_fused_decode(vals, src, w, dvec, B, bt=bt,
                                         t_tile=t_tile, lane="triton")
    np.testing.assert_allclose(np.asarray(got_tr), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_decode_dtype_sweep(dtype):
    """bf16 tiles flow through every lane within bf16 tolerance of the f32
    result (tiles are upcast to f32 inside the kernels; the error budget is
    the bf16 storage rounding of vals, eps = 2**-8)."""
    vals, src, w, dvec, B = _fused_decode_case(seed=13)
    ref = np.asarray(ops.spmm_block_fused_decode(vals, src, w, dvec, B,
                                                 bt=128, lane="xla"))
    vq = vals.astype(dtype)
    scale = float(np.abs(ref).max())
    for lane in LANES:
        got = ops.spmm_block_fused_decode(vq, src, w, dvec, B, bt=128,
                                          lane=lane)
        atol = 1e-6 if dtype == jnp.float32 else 2 ** -8 * 4 * scale
        np.testing.assert_allclose(np.asarray(got), ref, atol=atol, rtol=2e-2)


def test_fused_decode_survivor_rebind_pack():
    """Over a real pack under a survivor rebind: the fused kernel fed the
    rebound plan's gathered weights and decode column equals the dense
    per-worker decode-weighted coded product."""
    from repro.core.coded_matmul import make_plan, pack_worker_tiles

    rng = np.random.default_rng(21)
    plan = make_plan(2, 2, num_workers=8, seed=4)
    surv = np.ones(8, dtype=bool)
    surv[3] = False
    rplan = plan.with_survivors(surv)
    s, r, t, bs = 32, 32, 24, 8
    m, n = 2, 2
    br, bt = r // m, t // n
    mask = rng.random((s // bs, r // bs)) < 0.6
    A = rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
    B = rng.standard_normal((s, t)).astype(np.float32)
    ell = dense_to_block_ell(A.astype(np.float32), block_size=bs)
    pack = pack_worker_tiles(ell, plan)  # packs survive rebinds unchanged
    for k in range(rplan.num_workers):
        dcol = rplan.decode[:, k].astype(np.float32) * float(surv[k])
        got = ops.spmm_block_fused_decode(
            jnp.asarray(pack.vals[k]), jnp.asarray(pack.src[k]),
            jnp.asarray(pack.wslot[k]), jnp.asarray(dcol), jnp.asarray(B),
            bt=bt)
        Ct = np.zeros((br, bt), np.float32)
        for l in range(rplan.max_degree):
            wgt = rplan.weights[k, l]
            if wgt == 0.0:
                continue
            i, j = divmod(int(rplan.cols[k, l]), n)
            Ct += wgt * (A[:, i * br:(i + 1) * br].T
                         @ B[:, j * bt:(j + 1) * bt]).astype(np.float32)
        want = dcol[:, None, None] * Ct[None]
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-3, rtol=1e-3)


def test_resolve_lane_precedence(monkeypatch):
    from repro.kernels.spmm_block import resolve_lane

    monkeypatch.delenv("REPRO_KERNEL_LANE", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert jax.default_backend() not in ("tpu", "gpu")
    assert resolve_lane() == "xla"                 # backend default on CPU
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert resolve_lane() == "tpu"                 # interpret opt-in
    monkeypatch.setenv("REPRO_KERNEL_LANE", "triton")
    assert resolve_lane() == "triton"              # env beats interpret
    assert resolve_lane("xla") == "xla"            # explicit arg beats env
    monkeypatch.setenv("REPRO_KERNEL_LANE", "cuda")
    with pytest.raises(ValueError, match="cuda"):
        resolve_lane()
    with pytest.raises(ValueError, match="not in"):
        resolve_lane("metal")


def test_plan_t_tiling_prime_bt_pads():
    """Regression: prime bt used to degrade to t_tile=1 (one grid step per
    column).  Now the t axis pads to a multiple of 8 and tiles properly."""
    from repro.core.coded_matmul import _plan_t_tiling

    t_tile, bt_pad = _plan_t_tiling(13)            # small prime: one tile, fine
    assert (t_tile, bt_pad) == (13, 13)
    t_tile, bt_pad = _plan_t_tiling(128)           # no padding when aligned
    assert (t_tile, bt_pad) == (128, 128)
    t_tile, bt_pad = _plan_t_tiling(24)            # divisor exists: keep bt
    assert bt_pad == 24 and 24 % t_tile == 0
    t_tile, bt_pad = _plan_t_tiling(251)           # prime > cap: used to be 1
    assert t_tile >= 8 and bt_pad % 8 == 0
    assert bt_pad >= 251 and bt_pad % t_tile == 0
    t_tile, bt_pad = _plan_t_tiling(2 * 127)       # 2*prime > cap: was 2
    assert t_tile >= 8 and bt_pad >= 254 and bt_pad % t_tile == 0


def test_fused_decode_prime_bt_end_to_end():
    """The padded-t staging path: a per-worker coded product with prime
    bt=251 (> the 128 tile cap, so the t axis genuinely pads to 256) must
    match the dense reference after the pad+slice."""
    from repro.core.coded_matmul import (
        _make_block_sparse_fused_decode, make_plan, pack_worker_tiles)

    rng = np.random.default_rng(17)
    plan = make_plan(2, 2, num_workers=8, seed=4)
    s, r, bs = 32, 16, 8
    n, bt = 2, 251
    t = n * bt
    mask = rng.random((s // bs, r // bs)) < 0.7
    A = (rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
         ).astype(np.float32)
    B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
    ell = dense_to_block_ell(A, block_size=bs)
    pack = pack_worker_tiles(ell, plan)
    fused = _make_block_sparse_fused_decode(plan, pack, bt)
    dvec = jnp.asarray(rng.standard_normal(4).astype(np.float32))
    for k in [0, 3]:
        got = np.asarray(fused(jnp.asarray(k), jnp.asarray(A), B, dvec))
        assert got.shape == (4, r // 2, bt)
        Ct = np.zeros((r // 2, bt), np.float32)
        for l in range(plan.max_degree):
            wgt = plan.weights[k, l]
            if wgt == 0.0:
                continue
            i, j = divmod(int(plan.cols[k, l]), n)
            Ct += wgt * (A[:, i * (r // 2):(i + 1) * (r // 2)].T
                         @ np.asarray(B)[:, j * bt:(j + 1) * bt])
        np.testing.assert_allclose(
            got, np.asarray(dvec)[:, None, None] * Ct[None],
            atol=1e-3, rtol=1e-3)


# ------------------------- format round-trips ------------------------------

if given is not None:
    @given(data=st.data())
    @settings(**SETTINGS)
    def test_block_ell_roundtrip(data):
        bs = data.draw(st.sampled_from([4, 8]))
        RB = data.draw(st.integers(1, 5))
        CB = data.draw(st.integers(1, 5))
        density = data.draw(st.floats(0.0, 1.0))
        seed = data.draw(st.integers(0, 10_000))
        rng = np.random.default_rng(seed)
        mask = rng.random((RB, CB)) < density
        A = rng.standard_normal((RB * bs, CB * bs)) * np.kron(mask, np.ones((bs, bs)))
        ell = dense_to_block_ell(A, block_size=bs)
        np.testing.assert_array_equal(block_ell_to_dense(ell), A)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-test.txt)")
    def test_property_sweeps_need_hypothesis():
        pass
