"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + a grad step + prefill/decode on CPU; asserts output
shapes and absence of NaNs.  Full configs are exercised only by the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import build
from repro.training.data import SyntheticCorpus

ALL_ARCHS = sorted(configs.ARCHS)

B, S = 2, 16


def _setup(name):
    cfg = configs.get(name).reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    batch = SyntheticCorpus(cfg, B, S, seed=1).make_batch(0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    return cfg, model, params, batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_and_loss(name):
    cfg, model, params, batch = _setup(name)
    x, aux, _ = model.forward(params, batch["tokens"],
                              extras={k: v for k, v in batch.items()
                                      if k in ("frames", "vision")})
    assert x.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_grad_step_finite(name):
    cfg, model, params, batch = _setup(name)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # at least most params get nonzero gradient signal
    nonzero = sum(float(jnp.any(g != 0)) for g in flat)
    assert nonzero / len(flat) > 0.5


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_then_decode(name):
    cfg, model, params, batch = _setup(name)
    extras = {k: v for k, v in batch.items() if k in ("frames", "vision")}
    logits, cache = model.prefill(params, batch["tokens"], extras=extras,
                                  max_seq=S + 8, cache_dtype=jnp.float32)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["pos"]) == S
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, cache, tok)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    assert int(cache["pos"]) == S + 3


@pytest.mark.parametrize("name", ["internlm2-1.8b", "rwkv6-3b", "jamba-1.5-large-398b"])
def test_decode_matches_forward(name):
    """Prefill+decode must agree with a full forward pass on the same tokens
    (the KV/state caches are exact, not approximations)."""
    cfg, model, params, batch = _setup(name)
    tokens = batch["tokens"]
    x_full, _, _ = model.forward(params, tokens)
    logits_full = model.logits(params, x_full)

    # prefill on the first S-3 tokens, then decode 3 tokens one by one
    k = S - 3
    logits_p, cache = model.prefill(params, tokens[:, :k], max_seq=S + 4,
                                    cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(logits_full[:, k - 1]),
                               atol=2e-3, rtol=2e-3)
    for i in range(3):
        logits_d, cache = model.decode_step(params, cache, tokens[:, k + i:k + i + 1])
        np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                                   np.asarray(logits_full[:, k + i]),
                                   atol=2e-3, rtol=2e-3,
                                   err_msg=f"decode step {i}")


def test_params_count_close_to_actual():
    for name in ALL_ARCHS:
        cfg = configs.get(name)
        model = build(cfg)
        shapes = model.shapes()
        actual = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        analytic = cfg.params_count()
        assert abs(actual - analytic) / actual < 0.15, (
            f"{name}: analytic {analytic:,} vs actual {actual:,}")
