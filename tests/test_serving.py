import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core import schemes
from repro.models import build
from repro.runtime.executor import JobMux, MuxJob
from repro.serving.loadgen import ClosedLoopLoad, TenantSpec, poisson_trace
from repro.serving.scheduler import (SLO, ContinuousBatcher, Request,
                                     ServingMetrics, percentile)
from repro.serving.serve_step import (generate, jitted_decode_step,
                                      make_decode_step, make_prefill_step)
from repro.training.data import SyntheticCorpus, input_specs


def test_generate_greedy_deterministic():
    cfg = configs.get("internlm2-1.8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    prompt = jnp.asarray(
        SyntheticCorpus(cfg, 2, 8, seed=0).make_batch(0)["tokens"])
    out1 = generate(model, params, prompt, steps=5, max_seq=16,
                    cache_dtype=jnp.float32)
    out2 = generate(model, params, prompt, steps=5, max_seq=16,
                    cache_dtype=jnp.float32)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.dtype == jnp.int32
    assert int(out1.min()) >= 0 and int(out1.max()) < cfg.vocab_size


def test_temperature_sampling_uses_rng():
    cfg = configs.get("internlm2-1.8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(1), jnp.float32)
    prompt = jnp.asarray(
        SyntheticCorpus(cfg, 1, 8, seed=1).make_batch(0)["tokens"])
    outs = [np.asarray(generate(model, params, prompt, steps=8, max_seq=20,
                                temperature=2.0, rng=jax.random.key(s),
                                cache_dtype=jnp.float32)) for s in (0, 1)]
    assert not np.array_equal(outs[0], outs[1]), "different rng, different text"


@pytest.mark.parametrize("name,kind,extra", [
    ("internlm2-1.8b", "train", None),
    ("whisper-medium", "train", "frames"),
    ("llama-3.2-vision-11b", "prefill", "vision"),
    ("qwen3-moe-30b-a3b", "decode", None),
])
def test_input_specs_cover_model_inputs(name, kind, extra):
    cfg = configs.get(name)
    spec = input_specs(cfg, batch=4, seq=64, kind=kind)
    assert spec["tokens"].shape == ((4, 64) if kind != "decode" else (4, 1))
    if extra and kind != "decode":
        assert extra in spec
        assert spec[extra].shape[0] == 4
    if kind == "train":
        assert spec["labels"].shape == (4, 64)


def test_jitted_decode_step_is_cached_per_model_and_temperature():
    cfg = configs.get("internlm2-1.8b").reduced()
    model = build(cfg)
    # the serving steady state: repeated lookups return the SAME jitted
    # callable (generate used to re-wrap jax.jit every call)
    d1 = jitted_decode_step(model, 0.0)
    d2 = jitted_decode_step(model, 0.0)
    assert d1 is d2
    assert jitted_decode_step(model, 1.0) is not d1
    other = build(cfg)
    assert jitted_decode_step(other, 0.0) is not d1


# ---------------------------- scheduler ------------------------------------


def _req(rid, tenant, arrival=0.0, prompt_len=4, max_new=2, slo=None):
    return Request(rid=rid, tenant=tenant, arrival_time=arrival,
                   prompt_len=prompt_len, max_new_tokens=max_new,
                   slo=slo or SLO())


def test_batcher_never_exceeds_max_batch():
    b = ContinuousBatcher(max_batch=2)
    for i in range(5):
        b.submit(_req(f"r{i}", "t"))
    admitted = b.admit(now=0.0)
    assert len(admitted) == 2 and len(b.running) == 2 and b.waiting == 3
    # a retired slot is refilled on the next admit (continuous batching)
    b.retire(admitted[0], now=1.0)
    more = b.admit(now=1.0)
    assert len(more) == 1 and len(b.running) == 2 and b.waiting == 2


def test_batcher_fifo_within_tenant():
    b = ContinuousBatcher(max_batch=1)
    for i in range(4):
        b.submit(_req(f"a{i}", "alpha"))
    order = []
    while b.waiting:
        (req,) = b.admit(now=0.0)
        order.append(req.rid)
        b.retire(req, now=0.0)
    assert order == ["a0", "a1", "a2", "a3"]


def test_batcher_round_robin_across_tenants():
    b = ContinuousBatcher(max_batch=1)
    for i in range(2):
        b.submit(_req(f"a{i}", "alpha"))
        b.submit(_req(f"b{i}", "beta"))
    order = []
    while b.waiting:
        (req,) = b.admit(now=0.0)
        order.append(req.rid)
        b.retire(req, now=0.0)
    # rotation across tenants, FIFO within each
    assert order == ["a0", "b0", "a1", "b1"]


def test_slo_accounting_and_percentiles():
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([5.0], 99) == 5.0
    assert math.isnan(percentile([], 50))

    m = ServingMetrics()
    ok = _req("ok", "t", arrival=0.0, slo=SLO(ttft=1.0, per_token=1.0))
    ok.first_token_time = 0.5
    ok.finish_time = 2.0
    ok.token_latencies = [0.2, 0.4]
    ok.tokens = [1, 2, 3]
    slow = _req("slow", "t", arrival=0.0, slo=SLO(ttft=1.0, per_token=0.1))
    slow.first_token_time = 0.5
    slow.finish_time = 2.0
    slow.token_latencies = [0.2, 0.4]   # tpot 0.3 > 0.1 -> SLO miss
    failed = _req("dead", "t")
    failed.error = "worker gone"
    failed.finish_time = 1.0
    for r in (ok, slow, failed):
        m.record(r)
    s = m.summary()
    assert s["requests"] == 3 and s["completed"] == 2 and s["failed"] == 1
    # a failed request is an SLO miss, not a dropped sample
    assert s["slo_attainment"] == pytest.approx(1 / 3)
    assert s["token_p50_ms"] == pytest.approx(300.0)
    assert s["ttft_p50_ms"] == pytest.approx(500.0)


# ---------------------------- loadgen --------------------------------------


def test_poisson_trace_deterministic_and_per_tenant_independent():
    tenants = [TenantSpec("a", rate=20.0), TenantSpec("b", rate=10.0)]
    t1 = poisson_trace(tenants, horizon=1.0, seed=3)
    t2 = poisson_trace(tenants, horizon=1.0, seed=3)
    assert [(r.rid, r.arrival_time) for r in t1] == \
           [(r.rid, r.arrival_time) for r in t2]
    assert all(t1[i].arrival_time <= t1[i + 1].arrival_time
               for i in range(len(t1) - 1))
    # adding a tenant must not perturb an existing tenant's arrivals
    t3 = poisson_trace(tenants + [TenantSpec("c", rate=5.0)],
                       horizon=1.0, seed=3)
    assert [(r.rid, r.arrival_time) for r in t3 if r.tenant == "a"] == \
           [(r.rid, r.arrival_time) for r in t1 if r.tenant == "a"]


def test_closed_loop_keeps_concurrency():
    tenants = [TenantSpec("a", rate=1.0, weight=2.0),
               TenantSpec("b", rate=1.0, weight=1.0)]
    load = ClosedLoopLoad(tenants, concurrency=3, total=7, seed=0)
    wave = load.initial()
    assert len(wave) == 3
    assert sorted(r.tenant for r in wave) == ["a", "a", "b"]
    issued = len(wave)
    while True:
        nxt = load.next_request(wave[0], now=1.0)
        if nxt is None:
            break
        assert nxt.tenant == wave[0].tenant  # client keeps its tenant
        issued += 1
    assert issued == 7


# ---------------------------- JobMux ---------------------------------------


def _mux_jobs(n_jobs, num_workers, seed=0):
    rng = np.random.default_rng(seed)
    jobs, expected = [], []
    for k in range(n_jobs):
        m_s, n_s = 2, 2
        A = rng.standard_normal((8, 4 * (k + 1)))
        B = rng.standard_normal((8, 6))
        A_blocks = np.array_split(A, m_s, axis=1)
        B_blocks = np.array_split(B, n_s, axis=1)
        code = schemes.sparse_code(m_s, n_s, num_workers, seed=k)
        jobs.append(MuxJob(code=code, A_blocks=A_blocks, B_blocks=B_blocks,
                           n=n_s, num_chunks=2, tag=f"job{k}"))
        expected.append([A_blocks[i].T @ B_blocks[j]
                         for i in range(m_s) for j in range(n_s)])
    return jobs, expected


def test_jobmux_three_concurrent_jobs_exact_decode_sim():
    jobs, expected = _mux_jobs(3, num_workers=8)
    mux = JobMux(8, source="sim")
    results = mux.run(jobs)
    assert len(results) == 3
    for res, exp in zip(results, expected):
        assert res.ok, res.error
        assert res.report.decode_stats["concurrent_jobs"] == 3
        for got, want in zip(res.report.blocks, exp):
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-7,
                                       atol=1e-9)


def test_jobmux_live_pool_persists_across_batches():
    jobs, expected = _mux_jobs(3, num_workers=6, seed=1)
    with JobMux(6, source="live", straggler_sleep={0: 0.1},
                timeout=10.0) as mux:
        for _ in range(2):  # same pool, two batches
            results = mux.run(jobs)
            for res, exp in zip(results, expected):
                assert res.ok, res.error
                for got, want in zip(res.report.blocks, exp):
                    np.testing.assert_allclose(np.asarray(got), want,
                                               rtol=1e-7, atol=1e-9)


def test_jobmux_failure_isolated_to_uncoded_job():
    rng = np.random.default_rng(2)
    A = rng.standard_normal((6, 4))
    B = rng.standard_normal((6, 4))
    A_blocks = np.array_split(A, 2, axis=1)
    B_blocks = np.array_split(B, 2, axis=1)
    uncoded = MuxJob(code=schemes.uncoded(2, 2), A_blocks=A_blocks,
                     B_blocks=B_blocks, n=2, tag="uncoded")
    coded = MuxJob(code=schemes.sparse_code(2, 2, 6, seed=3),
                   A_blocks=A_blocks, B_blocks=B_blocks, n=2, tag="coded")
    mux = JobMux(6, source="sim", dead_workers=(1,))
    by_tag = {r.tag: r for r in mux.run([uncoded, coded])}
    assert not by_tag["uncoded"].ok
    assert "not decodable" in by_tag["uncoded"].error
    assert by_tag["coded"].ok, by_tag["coded"].error


def test_jobmux_reports_shared_pack_cache_stats():
    jobs, _ = _mux_jobs(3, num_workers=8, seed=4)
    res = JobMux(8, source="sim").run(jobs)[0]
    pc = res.report.decode_stats["pack_cache"]
    assert set(pc) == {"entries", "hits", "misses", "evictions"}


# ---------------------------- engine ---------------------------------------


def _moe_cfg():
    return configs.get("qwen3-moe-30b-a3b").reduced()


def _tiny_trace(max_new=2, n=3):
    tenants = [TenantSpec("a", rate=60.0, prompt_len=5, max_new_tokens=max_new),
               TenantSpec("b", rate=40.0, prompt_len=7, max_new_tokens=max_new)]
    return poisson_trace(tenants, horizon=0.1, seed=9, max_requests=n)


def test_engine_coded_uncoded_token_parity():
    from repro.serving.engine import ServingEngine

    toks = {}
    for coded in (True, False):
        eng = ServingEngine(_moe_cfg(), coded=coded, num_workers=6,
                            source="sim", unit_block_time=1e-3, max_batch=2)
        with eng:
            metrics = eng.run(_tiny_trace())
        assert all(r.completed for r in metrics.requests), [
            (r.rid, r.error) for r in metrics.requests]
        toks[coded] = {r.rid: r.tokens for r in metrics.requests}
    # the code on the wire must not change the text
    assert toks[True] == toks[False]


def test_engine_coded_survives_dead_worker_uncoded_fails():
    from repro.serving.engine import ServingEngine

    outcomes = {}
    for coded in (True, False):
        eng = ServingEngine(_moe_cfg(), coded=coded, num_workers=6,
                            source="sim", unit_block_time=1e-3,
                            dead_workers=(0,), max_batch=2)
        with eng:
            metrics = eng.run(_tiny_trace())
        outcomes[coded] = metrics
    assert all(r.completed for r in outcomes[True].requests)
    assert outcomes[True].summary()["straggler_recoveries"] >= 1
    # worker 0 is inside the uncoded footprint: every request fails, and the
    # failure is accounted as an SLO miss
    assert all(not r.completed for r in outcomes[False].requests)
    assert outcomes[False].summary()["slo_attainment"] == 0.0


def test_engine_metrics_schema_and_ttft():
    from repro.serving.engine import ServingEngine

    eng = ServingEngine(_moe_cfg(), coded=True, num_workers=6, source="sim",
                        unit_block_time=1e-3, max_batch=2)
    with eng:
        s = eng.run(_tiny_trace()).summary()
    assert s["requests"] == 3 and s["completed"] == 3
    assert set(s["by_tenant"]) <= {"a", "b"}
    for key in ("ttft_p50_ms", "ttft_p95_ms", "token_p50_ms",
                "token_p95_ms", "token_p99_ms"):
        assert s[key] is not None and s[key] >= 0.0
    assert s["tokens"] == 3 * 2
