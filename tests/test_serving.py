import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import build
from repro.serving.serve_step import generate, make_decode_step, make_prefill_step
from repro.training.data import SyntheticCorpus, input_specs


def test_generate_greedy_deterministic():
    cfg = configs.get("internlm2-1.8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    prompt = jnp.asarray(
        SyntheticCorpus(cfg, 2, 8, seed=0).make_batch(0)["tokens"])
    out1 = generate(model, params, prompt, steps=5, max_seq=16,
                    cache_dtype=jnp.float32)
    out2 = generate(model, params, prompt, steps=5, max_seq=16,
                    cache_dtype=jnp.float32)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.dtype == jnp.int32
    assert int(out1.min()) >= 0 and int(out1.max()) < cfg.vocab_size


def test_temperature_sampling_uses_rng():
    cfg = configs.get("internlm2-1.8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(1), jnp.float32)
    prompt = jnp.asarray(
        SyntheticCorpus(cfg, 1, 8, seed=1).make_batch(0)["tokens"])
    outs = [np.asarray(generate(model, params, prompt, steps=8, max_seq=20,
                                temperature=2.0, rng=jax.random.key(s),
                                cache_dtype=jnp.float32)) for s in (0, 1)]
    assert not np.array_equal(outs[0], outs[1]), "different rng, different text"


@pytest.mark.parametrize("name,kind,extra", [
    ("internlm2-1.8b", "train", None),
    ("whisper-medium", "train", "frames"),
    ("llama-3.2-vision-11b", "prefill", "vision"),
    ("qwen3-moe-30b-a3b", "decode", None),
])
def test_input_specs_cover_model_inputs(name, kind, extra):
    cfg = configs.get(name)
    spec = input_specs(cfg, batch=4, seq=64, kind=kind)
    assert spec["tokens"].shape == ((4, 64) if kind != "decode" else (4, 1))
    if extra and kind != "decode":
        assert extra in spec
        assert spec[extra].shape[0] == 4
    if kind == "train":
        assert spec["labels"].shape == (4, 64)
