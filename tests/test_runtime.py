import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import schemes
from repro.core.encoder import split_blocks
from repro.runtime import (
    ExponentialStragglers,
    NoStragglers,
    SlowWorkers,
    run_coded_job,
    run_live_job,
)


def _blocks(rng, d, shape=(6, 7)):
    return [rng.random(shape) for _ in range(d)]


def test_simulated_job_sparse_code_beats_uncoded_with_stragglers():
    m, n, N = 3, 3, 24
    rng = np.random.default_rng(0)
    blocks = _blocks(rng, m * n)
    strag = SlowWorkers(num_slow=3, slowdown=10.0)

    totals = {}
    for name, code in [
        ("uncoded", schemes.uncoded(m, n)),
        ("sparse", schemes.sparse_code(m, n, N, seed=1)),
    ]:
        reps = [
            run_coded_job(code, blocks, strag, rng=np.random.default_rng(t),
                          unit_block_time=0.01)
            for t in range(10)
        ]
        totals[name] = np.mean([r.sim_compute_time for r in reps])
    # uncoded must wait for the slowest worker; sparse code routes around it
    assert totals["sparse"] < totals["uncoded"]


def test_simulated_job_decodes_correctly():
    m, n, N = 2, 3, 16
    rng = np.random.default_rng(1)
    blocks = _blocks(rng, m * n)
    code = schemes.sparse_code(m, n, N, seed=2)
    rep = run_coded_job(code, blocks, ExponentialStragglers(0.5),
                        rng=rng, keep_blocks=True)
    assert rep.workers_used <= N
    for got, want in zip(rep.blocks, blocks):
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-8)
    assert rep.total_time > 0


def test_live_job_with_real_sparse_matmul_and_straggler():
    m = n = 2
    rng = np.random.default_rng(3)
    A = sp.random(40, 16, density=0.3, format="csc",
                  random_state=np.random.RandomState(0))
    B = sp.random(40, 20, density=0.3, format="csc",
                  random_state=np.random.RandomState(1))
    code = schemes.sparse_code(m, n, N=10, seed=4)
    # worker 0 sleeps way longer than the job: must never be waited on
    rep = run_live_job(code, split_blocks(A, m), split_blocks(B, n), n,
                       straggler_sleep={0: 30.0})
    assert rep.total_time < 10.0
    C = (A.T @ B).toarray()
    br, bt = C.shape[0] // m, C.shape[1] // n
    for i in range(m):
        for j in range(n):
            got = rep.blocks[i * n + j]
            got = got.toarray() if sp.issparse(got) else np.asarray(got)
            np.testing.assert_allclose(got, C[i*br:(i+1)*br, j*bt:(j+1)*bt], atol=1e-8)


def test_all_schemes_complete_under_stragglers():
    m, n, N = 2, 2, 12
    rng = np.random.default_rng(5)
    blocks = _blocks(rng, 4)
    strag = SlowWorkers(num_slow=2, slowdown=8.0)
    for name, ctor in schemes.SCHEMES.items():
        code = ctor(m, n) if name == "uncoded" else ctor(m, n, N)
        rep = run_coded_job(code, blocks, strag, rng=np.random.default_rng(9),
                            keep_blocks=True)
        for got, want in zip(rep.blocks, blocks):
            got = got.toarray() if sp.issparse(got) else np.asarray(got)
            np.testing.assert_allclose(got, want, atol=1e-5,
                                       err_msg=f"scheme {name}")


def test_live_job_chunked_harvests_partial_straggler():
    """q=3 live run: the straggler's finished chunks are usable equations
    and the decoded product is still exact."""
    m = n = 2
    A = sp.random(40, 16, density=0.3, format="csc",
                  random_state=np.random.RandomState(0))
    B = sp.random(40, 20, density=0.3, format="csc",
                  random_state=np.random.RandomState(1))
    code = schemes.sparse_code(m, n, N=10, seed=4)
    rep = run_live_job(code, split_blocks(A, m), split_blocks(B, n), n,
                       straggler_sleep={0: 30.0}, num_chunks=3)
    assert rep.total_time < 10.0
    assert rep.num_chunks == 3 and rep.chunks_used > 0
    C = (A.T @ B).toarray()
    br, bt = C.shape[0] // m, C.shape[1] // n
    for i in range(m):
        for j in range(n):
            got = rep.blocks[i * n + j]
            got = got.toarray() if sp.issparse(got) else np.asarray(got)
            np.testing.assert_allclose(got, C[i*br:(i+1)*br, j*bt:(j+1)*bt], atol=1e-8)


def test_live_job_hung_worker_raises_decoding_error():
    """A worker that never reports surfaces as DecodingError naming it,
    not a bare queue.Empty."""
    import queue

    from repro.core.decoder import DecodingError

    m = n = 2
    rng = np.random.default_rng(7)
    A = sp.random(16, 8, density=0.5, format="csc",
                  random_state=np.random.RandomState(2))
    B = sp.random(16, 8, density=0.5, format="csc",
                  random_state=np.random.RandomState(3))
    code = schemes.uncoded(m, n)  # needs ALL workers: a hang cannot decode
    try:
        run_live_job(code, split_blocks(A, m), split_blocks(B, n), n,
                     straggler_sleep={2: 30.0}, timeout=0.5)
        raise AssertionError("expected DecodingError for the hung worker")
    except DecodingError as e:
        assert "2" in str(e) and "never reported" in str(e)
    except queue.Empty:  # pragma: no cover
        raise AssertionError("queue.Empty leaked to the caller")


def test_consume_events_out_of_order_chunk_raises():
    """Ordered sub-task streams: a chunk arriving ahead of its predecessor
    is a protocol violation, not a recoverable event."""
    from repro.runtime.executor import _consume_events

    chunked = schemes.sparse_code(2, 2, N=4, seed=4).chunked(2)

    def events():
        yield 0.0, 0, 1, {}  # chunk 1 before chunk 0

    with pytest.raises(ValueError, match="out of order"):
        _consume_events(chunked, events())


def test_consume_events_dry_source_names_never_and_stalled():
    """A dry source's DecodingError distinguishes workers that never
    reported from workers that stalled mid-stream."""
    from repro.core.decoder import DecodingError
    from repro.runtime.executor import (
        _EventSourceDry,
        _chunk_result,
        _consume_events,
    )

    rng = np.random.default_rng(11)
    blocks = _blocks(rng, 4)
    chunked = schemes.sparse_code(2, 2, N=4, seed=4).chunked(2)

    def events():
        # worker 0 delivers chunk 0 of 2 then the source dries up; workers
        # 1..3 never say anything
        payload = {r: _chunk_result(chunked, r, blocks)
                   for r in chunked.expanded_rows(0, 0)}
        yield 0.1, 0, 0, payload
        raise _EventSourceDry("transport gave up")

    with pytest.raises(DecodingError) as ei:
        _consume_events(chunked, events())
    msg = str(ei.value)
    assert "transport gave up" in msg
    assert "[1, 2, 3] never reported" in msg
    assert "[0] stalled mid-stream" in msg


def test_consume_events_exact_test_gets_last_word_after_dry():
    """The rank tracker is a float gate: rows it rejects as dependent can
    still be exactly decodable, and after the source dries up the exact
    test -- not the tracker -- must have the last word."""
    from repro.runtime.executor import _EventSourceDry, _consume_events

    # second row is within the tracker's 1e-10 tolerance of the first but
    # exactly independent: matrix_rank (eps-scale tolerance) sees rank 2
    M = sp.csr_matrix(np.array([[1.0, 0.0], [1.0, 1e-12]]))
    code = schemes.CodeInstance(
        name="toy", M=M, worker_rows=[[0], [1]],
        cost_factor=np.ones(2), decode_kind="dense")
    chunked = code.chunked(1)

    def events():
        yield 0.1, 0, 0, {0: np.ones((2, 2))}
        yield 0.2, 1, 0, {1: np.ones((2, 2))}
        raise _EventSourceDry("no more arrivals")

    state = _consume_events(chunked, events())
    assert state.tracker_rank == 1          # the tracker never filled...
    assert state.exact_checks == 1          # ...so only the last word ran
    assert state.pairs == [(0, 0), (1, 0)]


def test_live_job_dead_thread_fails_fast_not_timeout():
    """A worker thread that dies (exception) posts its terminal sentinel:
    the master stops expecting it instead of waiting out the full timeout."""
    import time as _time

    from repro.core.decoder import DecodingError
    from repro.runtime import executor

    m = n = 2
    A = sp.random(16, 8, density=0.5, format="csc",
                  random_state=np.random.RandomState(2))
    B = sp.random(16, 8, density=0.5, format="csc",
                  random_state=np.random.RandomState(3))
    code = schemes.uncoded(m, n)  # worker 2 is essential

    real_encode = executor.encode_blocks

    def dying_encode(chunk, A_blocks, B_blocks, n_):
        if chunk.worker == 2:  # task rows == worker ids for uncoded
            raise RuntimeError("simulated worker crash")
        return real_encode(chunk, A_blocks, B_blocks, n_)

    executor.encode_blocks = dying_encode
    try:
        t0 = _time.perf_counter()
        with pytest.raises(DecodingError) as ei:
            run_live_job(code, split_blocks(A, m), split_blocks(B, n), n,
                         timeout=30.0)
        elapsed = _time.perf_counter() - t0
    finally:
        executor.encode_blocks = real_encode
    assert "exited before delivering" in str(ei.value)
    assert "[2]" in str(ei.value)
    assert elapsed < 10.0  # sentinel, not the 30s queue timeout


def test_live_job_joins_worker_threads_on_early_decode():
    """Decoding early must not leak straggler threads that keep sleeping or
    computing in the background (they hold A/B block references alive)."""
    import threading

    m = n = 2
    A = sp.random(40, 16, density=0.3, format="csc",
                  random_state=np.random.RandomState(0))
    B = sp.random(40, 20, density=0.3, format="csc",
                  random_state=np.random.RandomState(1))
    code = schemes.sparse_code(m, n, N=10, seed=4)
    rep = run_live_job(code, split_blocks(A, m), split_blocks(B, n), n,
                       straggler_sleep={0: 30.0, 1: 30.0}, num_chunks=2)
    assert rep.total_time < 10.0
    leaked = [t.name for t in threading.enumerate()
              if t.name.startswith("live-worker-") and t.is_alive()]
    assert leaked == [], f"leaked worker threads: {leaked}"


def test_decode_stats_populated_on_host_paths():
    """Both host paths report the master loop's bookkeeping: arrivals,
    tracker state, exact-check count, and (empty) fault summary."""
    m, n, N = 2, 2, 12
    rng = np.random.default_rng(5)
    blocks = _blocks(rng, 4)
    code = schemes.sparse_code(m, n, N, seed=2)
    rep = run_coded_job(code, blocks, SlowWorkers(num_slow=2, slowdown=8.0),
                        rng=np.random.default_rng(9), num_chunks=3)
    for rep_ in (rep,):
        stats = rep_.decode_stats
        assert stats["arrivals_consumed"] == rep_.chunks_used > 0
        assert stats["tracker_rank"] == m * n
        assert stats["tracker_rows"] >= stats["tracker_rank"]
        assert stats["exact_checks"] >= 1
        assert stats["faults"] == {}

    A = sp.random(16, 8, density=0.5, format="csc",
                  random_state=np.random.RandomState(2))
    B = sp.random(16, 8, density=0.5, format="csc",
                  random_state=np.random.RandomState(3))
    live = run_live_job(code, split_blocks(A, m), split_blocks(B, n), n)
    stats = live.decode_stats
    assert stats["arrivals_consumed"] == live.chunks_used > 0
    assert stats["tracker_rank"] == m * n
    assert stats["exact_checks"] >= 1
    assert stats["faults"] == {}


def test_run_device_job_single_device_both_backends():
    """The SPMD bridge: run_device_job stages coded_matmul on the default
    (single-device) mesh and returns the decoded product for each backend."""
    from repro.core.coded_matmul import make_plan
    from repro.runtime import run_device_job

    rng = np.random.default_rng(6)
    s, r, t = 24, 16, 8
    A = rng.standard_normal((s, r)).astype(np.float32)
    B = rng.standard_normal((s, t)).astype(np.float32)
    plan = make_plan(1, 1, num_workers=1, max_degree=1, seed=0)
    for backend in ("dense_scan", "block_sparse"):
        rep = run_device_job(A, B, plan, backend=backend, repeats=1)
        assert rep.scheme == f"spmd_{backend}"
        assert rep.decode_stats["on_device_decode"]
        assert rep.workers_used == rep.num_workers == 1
        np.testing.assert_allclose(rep.blocks[0], A.T @ B, atol=1e-3,
                                   rtol=1e-3, err_msg=backend)
