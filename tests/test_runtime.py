import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import schemes
from repro.core.encoder import split_blocks
from repro.runtime import (
    ExponentialStragglers,
    NoStragglers,
    SlowWorkers,
    run_coded_job,
    run_live_job,
)


def _blocks(rng, d, shape=(6, 7)):
    return [rng.random(shape) for _ in range(d)]


def test_simulated_job_sparse_code_beats_uncoded_with_stragglers():
    m, n, N = 3, 3, 24
    rng = np.random.default_rng(0)
    blocks = _blocks(rng, m * n)
    strag = SlowWorkers(num_slow=3, slowdown=10.0)

    totals = {}
    for name, code in [
        ("uncoded", schemes.uncoded(m, n)),
        ("sparse", schemes.sparse_code(m, n, N, seed=1)),
    ]:
        reps = [
            run_coded_job(code, blocks, strag, rng=np.random.default_rng(t),
                          unit_block_time=0.01)
            for t in range(10)
        ]
        totals[name] = np.mean([r.sim_compute_time for r in reps])
    # uncoded must wait for the slowest worker; sparse code routes around it
    assert totals["sparse"] < totals["uncoded"]


def test_simulated_job_decodes_correctly():
    m, n, N = 2, 3, 16
    rng = np.random.default_rng(1)
    blocks = _blocks(rng, m * n)
    code = schemes.sparse_code(m, n, N, seed=2)
    rep = run_coded_job(code, blocks, ExponentialStragglers(0.5),
                        rng=rng, keep_blocks=True)
    assert rep.workers_used <= N
    for got, want in zip(rep.blocks, blocks):
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-8)
    assert rep.total_time > 0


def test_live_job_with_real_sparse_matmul_and_straggler():
    m = n = 2
    rng = np.random.default_rng(3)
    A = sp.random(40, 16, density=0.3, format="csc",
                  random_state=np.random.RandomState(0))
    B = sp.random(40, 20, density=0.3, format="csc",
                  random_state=np.random.RandomState(1))
    code = schemes.sparse_code(m, n, N=10, seed=4)
    # worker 0 sleeps way longer than the job: must never be waited on
    rep = run_live_job(code, split_blocks(A, m), split_blocks(B, n), n,
                       straggler_sleep={0: 30.0})
    assert rep.total_time < 10.0
    C = (A.T @ B).toarray()
    br, bt = C.shape[0] // m, C.shape[1] // n
    for i in range(m):
        for j in range(n):
            got = rep.blocks[i * n + j]
            got = got.toarray() if sp.issparse(got) else np.asarray(got)
            np.testing.assert_allclose(got, C[i*br:(i+1)*br, j*bt:(j+1)*bt], atol=1e-8)


def test_all_schemes_complete_under_stragglers():
    m, n, N = 2, 2, 12
    rng = np.random.default_rng(5)
    blocks = _blocks(rng, 4)
    strag = SlowWorkers(num_slow=2, slowdown=8.0)
    for name, ctor in schemes.SCHEMES.items():
        code = ctor(m, n) if name == "uncoded" else ctor(m, n, N)
        rep = run_coded_job(code, blocks, strag, rng=np.random.default_rng(9),
                            keep_blocks=True)
        for got, want in zip(rep.blocks, blocks):
            got = got.toarray() if sp.issparse(got) else np.asarray(got)
            np.testing.assert_allclose(got, want, atol=1e-5,
                                       err_msg=f"scheme {name}")


def test_live_job_chunked_harvests_partial_straggler():
    """q=3 live run: the straggler's finished chunks are usable equations
    and the decoded product is still exact."""
    m = n = 2
    A = sp.random(40, 16, density=0.3, format="csc",
                  random_state=np.random.RandomState(0))
    B = sp.random(40, 20, density=0.3, format="csc",
                  random_state=np.random.RandomState(1))
    code = schemes.sparse_code(m, n, N=10, seed=4)
    rep = run_live_job(code, split_blocks(A, m), split_blocks(B, n), n,
                       straggler_sleep={0: 30.0}, num_chunks=3)
    assert rep.total_time < 10.0
    assert rep.num_chunks == 3 and rep.chunks_used > 0
    C = (A.T @ B).toarray()
    br, bt = C.shape[0] // m, C.shape[1] // n
    for i in range(m):
        for j in range(n):
            got = rep.blocks[i * n + j]
            got = got.toarray() if sp.issparse(got) else np.asarray(got)
            np.testing.assert_allclose(got, C[i*br:(i+1)*br, j*bt:(j+1)*bt], atol=1e-8)


def test_live_job_hung_worker_raises_decoding_error():
    """A worker that never reports surfaces as DecodingError naming it,
    not a bare queue.Empty."""
    import queue

    from repro.core.decoder import DecodingError

    m = n = 2
    rng = np.random.default_rng(7)
    A = sp.random(16, 8, density=0.5, format="csc",
                  random_state=np.random.RandomState(2))
    B = sp.random(16, 8, density=0.5, format="csc",
                  random_state=np.random.RandomState(3))
    code = schemes.uncoded(m, n)  # needs ALL workers: a hang cannot decode
    try:
        run_live_job(code, split_blocks(A, m), split_blocks(B, n), n,
                     straggler_sleep={2: 30.0}, timeout=0.5)
        raise AssertionError("expected DecodingError for the hung worker")
    except DecodingError as e:
        assert "2" in str(e) and "never reported" in str(e)
    except queue.Empty:  # pragma: no cover
        raise AssertionError("queue.Empty leaked to the caller")


def test_run_device_job_single_device_both_backends():
    """The SPMD bridge: run_device_job stages coded_matmul on the default
    (single-device) mesh and returns the decoded product for each backend."""
    from repro.core.coded_matmul import make_plan
    from repro.runtime import run_device_job

    rng = np.random.default_rng(6)
    s, r, t = 24, 16, 8
    A = rng.standard_normal((s, r)).astype(np.float32)
    B = rng.standard_normal((s, t)).astype(np.float32)
    plan = make_plan(1, 1, num_workers=1, max_degree=1, seed=0)
    for backend in ("dense_scan", "block_sparse"):
        rep = run_device_job(A, B, plan, backend=backend, repeats=1)
        assert rep.scheme == f"spmd_{backend}"
        assert rep.decode_stats["on_device_decode"]
        assert rep.workers_used == rep.num_workers == 1
        np.testing.assert_allclose(rep.blocks[0], A.T @ B, atol=1e-3,
                                   rtol=1e-3, err_msg=backend)
