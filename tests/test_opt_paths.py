"""The optimization flags (EXPERIMENTS.md section Perf) must be numerically
equivalent to the baseline paths -- forward losses, gradients, and decode
outputs agree within float tolerance."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import build
from repro.training.data import SyntheticCorpus

B, S = 2, 16


def _setup(name, **opts):
    cfg = configs.get(name).reduced()
    if opts:
        cfg = dataclasses.replace(cfg, **opts)
    model = build(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    batch = {k: jnp.asarray(v)
             for k, v in SyntheticCorpus(cfg, B, S, seed=2).make_batch(0).items()}
    return cfg, model, params, batch


def test_fused_ce_matches_baseline_loss_and_grads():
    _, m0, params, batch = _setup("internlm2-1.8b")
    _, m1, _, _ = _setup("internlm2-1.8b", opt_fused_ce=True)
    l0, g0 = jax.value_and_grad(m0.loss)(params, batch)
    l1, g1 = jax.value_and_grad(m1.loss)(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=2e-3)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        # fused backward runs its matmuls in bf16: tolerate bf16 noise
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-2, rtol=3e-2)


def test_moe_local_dispatch_matches_baseline():
    _, m0, params, batch = _setup("qwen3-moe-30b-a3b")
    _, m1, _, _ = _setup("qwen3-moe-30b-a3b", opt_moe_local_dispatch=True)
    l0 = float(m0.loss(params, batch))
    l1 = float(m1.loss(params, batch))
    # reduced configs disable capacity dropping, so routing is identical
    np.testing.assert_allclose(l0, l1, rtol=1e-4)
    x0, _, _ = m0.forward(params, batch["tokens"])
    x1, _, _ = m1.forward(params, batch["tokens"])
    np.testing.assert_allclose(np.asarray(x0), np.asarray(x1), atol=1e-4)


def test_moe_shardmap_combine_matches_vmap_8dev():
    """shard_map combine vs vmapped baseline on a real (2, 4) mesh
    (subprocess keeps this process single-device)."""
    import os
    import pathlib
    import subprocess
    import sys

    script = pathlib.Path(__file__).parent / "spmd_moe_combine_check.py"
    env = dict(os.environ,
               PYTHONPATH=str(pathlib.Path(__file__).parents[1] / "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-3000:]
    assert "ALL-OK" in out.stdout


@pytest.mark.parametrize("name", ["internlm2-1.8b", "jamba-1.5-large-398b"])
def test_onehot_cache_decode_matches_dus(name):
    _, m0, params, batch = _setup(name)
    _, m1, _, _ = _setup(name, opt_onehot_cache=True)
    tokens = batch["tokens"]
    k = S - 2
    lp0, c0 = m0.prefill(params, tokens[:, :k], max_seq=S + 2,
                         cache_dtype=jnp.float32)
    lp1, c1 = m1.prefill(params, tokens[:, :k], max_seq=S + 2,
                         cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lp0), np.asarray(lp1), atol=1e-4)
    for i in range(2):
        ld0, c0 = m0.decode_step(params, c0, tokens[:, k + i:k + i + 1])
        ld1, c1 = m1.decode_step(params, c1, tokens[:, k + i:k + i + 1])
        np.testing.assert_allclose(np.asarray(ld0), np.asarray(ld1), atol=1e-4,
                                   err_msg=f"step {i}")
