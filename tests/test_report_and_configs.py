import dataclasses
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import ArchConfig


def test_all_ten_archs_registered():
    expected = {
        "whisper-medium", "rwkv6-3b", "llama-3.2-vision-11b", "dbrx-132b",
        "qwen3-moe-30b-a3b", "internlm2-1.8b", "starcoder2-7b",
        "command-r-35b", "qwen2-7b", "jamba-1.5-large-398b",
    }
    assert expected <= set(configs.ARCHS)


def test_param_counts_match_public_scale():
    """Sanity: analytic parameter counts land near the published sizes."""
    expect = {
        "internlm2-1.8b": (1.5e9, 2.5e9),
        "qwen2-7b": (6e9, 9e9),
        "starcoder2-7b": (6e9, 9e9),
        "llama-3.2-vision-11b": (8e9, 13e9),
        "command-r-35b": (27e9, 40e9),  # 30.3B with the assigned ff/tied-embed
        "dbrx-132b": (110e9, 145e9),
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "jamba-1.5-large-398b": (330e9, 440e9),
        "rwkv6-3b": (2e9, 4e9),
        "whisper-medium": (0.5e9, 0.9e9),  # enc-dec with untied 51865 vocab
    }
    for name, (lo, hi) in expect.items():
        n = configs.get(name).params_count()
        assert lo < n < hi, f"{name}: {n:,} outside [{lo:,}, {hi:,}]"


def test_moe_active_params_below_total():
    for name in ("dbrx-132b", "qwen3-moe-30b-a3b", "jamba-1.5-large-398b"):
        cfg = configs.get(name)
        assert cfg.active_params_count() < 0.6 * cfg.params_count()


def test_layer_plans():
    jamba = configs.get("jamba-1.5-large-398b")
    plan = jamba.layer_plan()
    assert len(plan) == 8
    assert sum(1 for m, _ in plan if m == "attn") == 1
    assert plan[4][0] == "attn"  # attn_layer_offset = 4
    assert sum(1 for _, f in plan if f == "moe") == 4  # every other layer

    vlm = configs.get("llama-3.2-vision-11b")
    plan = vlm.layer_plan()
    assert sum(1 for m, _ in plan if m == "cross") == 1
    assert len(plan) == 5

    rwkv = configs.get("rwkv6-3b")
    assert all(m == "rwkv" for m, _ in rwkv.layer_plan())


def test_with_opts_validation():
    cfg = configs.get("internlm2-1.8b")
    c2 = cfg.with_opts(("fused_ce", "onehot_cache"))
    assert c2.opt_fused_ce and c2.opt_onehot_cache and not c2.opt_seq_parallel
    with pytest.raises(ValueError):
        cfg.with_opts(("not_a_real_opt",))


def test_reduced_configs_are_small():
    for name in configs.ARCHS:
        r = configs.get(name).reduced()
        assert r.params_count() < 5e7, name
        assert r.num_layers <= 16


def test_with_opts_rejects_bad_coded_backend():
    cfg = configs.get("internlm2-1.8b")
    assert cfg.coded_backend == "dense_scan"
    c2 = dataclasses.replace(cfg, coded_backend="block_sparse")
    assert c2.coded_backend == "block_sparse"
    with pytest.raises(ValueError, match="coded_backend"):
        dataclasses.replace(cfg, coded_backend="csr")


def test_coded_backend_validates_against_live_registry():
    """No hardcoded backend tuple: a backend registered AFTER configs were
    defined is immediately a legal coded_backend value."""
    from repro.core import coded_backends

    cfg = configs.get("internlm2-1.8b")
    name = "_test_backend"
    try:
        coded_backends.register_backend(name, doc="registry-desync probe")
        c2 = dataclasses.replace(cfg, coded_backend=name)
        assert c2.coded.backend == name
    finally:
        coded_backends._REGISTRY.pop(name, None)


def test_archconfig_embeds_coded_matmul_config():
    from repro.coded import CodedMatmulConfig

    cfg = configs.get("internlm2-1.8b")
    assert isinstance(cfg.coded, CodedMatmulConfig)
    # the alias mirrors the embedded config both ways
    c2 = dataclasses.replace(cfg, coded_backend="block_sparse")
    assert c2.coded.backend == "block_sparse"
    c3 = cfg.with_coded(backend="block_sparse", out_sharded=True)
    assert c3.coded_backend == "block_sparse" and c3.coded.out_sharded
    # a later replace of the alias keeps the other coded knobs
    c4 = dataclasses.replace(c3, coded_backend="dense_scan")
    assert c4.coded.backend == "dense_scan" and c4.coded.out_sharded


def test_archconfig_explicit_coded_not_clobbered_by_alias_default():
    # passing coded= alone must win: the alias default (None = follow
    # coded) may not silently reset an explicitly chosen backend
    from repro.coded import CodedMatmulConfig

    base = configs.get("internlm2-1.8b")
    cfg = dataclasses.replace(
        base, coded=CodedMatmulConfig(backend="block_sparse",
                                      out_sharded=True),
        coded_backend=None)
    assert cfg.coded.backend == "block_sparse" and cfg.coded.out_sharded
    assert cfg.coded_backend == "block_sparse"  # mirror follows coded
    direct = ArchConfig(
        name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=512,
        coded=CodedMatmulConfig(backend="block_sparse"))
    assert direct.coded.backend == "block_sparse"
    assert direct.coded_backend == "block_sparse"


_DRYRUN_RECORDS_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
import dataclasses, pathlib
import repro.configs as configs
from repro import compat
from repro.launch import dryrun, meshctx

outdir = pathlib.Path(sys.argv[1])
mesh = compat.make_mesh((4, 2), ("data", "model"),
                        axis_types=compat.auto_axis_types(2))
cfg = dataclasses.replace(
    configs.get("internlm2-1.8b"), num_layers=1, d_model=64, num_heads=4,
    num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512, max_seq=64)
dryrun.SHAPES["tiny_train"] = dict(seq=32, batch=8, kind="train")
dryrun.SHAPES["tiny_decode"] = dict(seq=32, batch=8, kind="decode")
for shp in ("tiny_train", "tiny_decode"):
    rec = dryrun.sweep_cell("internlm2-1.8b", shp, False, outdir,
                            mesh=mesh, cfg_override=cfg)
    assert rec["status"] == "ok", rec
# a family that fails must surface its error string as a record, not vanish
rec2 = dryrun.sweep_cell("no-such-arch", "tiny_train", False, outdir, mesh=mesh)
assert rec2["status"] == "error" and "KeyError" in rec2["error"], rec2
print("RECORDS-OK")
"""


def test_report_tables_render(tmp_path):
    from repro.launch.report import dryrun_table, perf_table, roofline_table

    # an empty/missing records dir renders an explicit placeholder, never a
    # silently bare header
    empty = dryrun_table(root=tmp_path / "nothing-here")
    assert "no dryrun records" in empty

    # real records: one compiled tiny cell + one errored family, produced by
    # the dryrun sweep machinery in a subprocess (8-device mesh isolation)
    outdir = tmp_path / "dryrun"
    outdir.mkdir()
    env = dict(os.environ, PYTHONPATH=str(pathlib.Path(__file__).parents[1] / "src"))
    proc = subprocess.run(
        [sys.executable, "-c", _DRYRUN_RECORDS_SCRIPT, str(outdir)],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-4000:]

    d = dryrun_table(root=outdir)
    assert d.count("|") > 50          # header + data rows
    assert "| ok |" in d              # the compiled family is a data row
    assert "error: KeyError" in d     # the failed family surfaces its error
    r = roofline_table()
    assert "dominant" in r or "arch" in r
    perf_table()  # renders without error even if variants are sparse
