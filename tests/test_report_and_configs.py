import dataclasses

import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import ArchConfig


def test_all_ten_archs_registered():
    expected = {
        "whisper-medium", "rwkv6-3b", "llama-3.2-vision-11b", "dbrx-132b",
        "qwen3-moe-30b-a3b", "internlm2-1.8b", "starcoder2-7b",
        "command-r-35b", "qwen2-7b", "jamba-1.5-large-398b",
    }
    assert expected <= set(configs.ARCHS)


def test_param_counts_match_public_scale():
    """Sanity: analytic parameter counts land near the published sizes."""
    expect = {
        "internlm2-1.8b": (1.5e9, 2.5e9),
        "qwen2-7b": (6e9, 9e9),
        "starcoder2-7b": (6e9, 9e9),
        "llama-3.2-vision-11b": (8e9, 13e9),
        "command-r-35b": (27e9, 40e9),  # 30.3B with the assigned ff/tied-embed
        "dbrx-132b": (110e9, 145e9),
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "jamba-1.5-large-398b": (330e9, 440e9),
        "rwkv6-3b": (2e9, 4e9),
        "whisper-medium": (0.5e9, 0.9e9),  # enc-dec with untied 51865 vocab
    }
    for name, (lo, hi) in expect.items():
        n = configs.get(name).params_count()
        assert lo < n < hi, f"{name}: {n:,} outside [{lo:,}, {hi:,}]"


def test_moe_active_params_below_total():
    for name in ("dbrx-132b", "qwen3-moe-30b-a3b", "jamba-1.5-large-398b"):
        cfg = configs.get(name)
        assert cfg.active_params_count() < 0.6 * cfg.params_count()


def test_layer_plans():
    jamba = configs.get("jamba-1.5-large-398b")
    plan = jamba.layer_plan()
    assert len(plan) == 8
    assert sum(1 for m, _ in plan if m == "attn") == 1
    assert plan[4][0] == "attn"  # attn_layer_offset = 4
    assert sum(1 for _, f in plan if f == "moe") == 4  # every other layer

    vlm = configs.get("llama-3.2-vision-11b")
    plan = vlm.layer_plan()
    assert sum(1 for m, _ in plan if m == "cross") == 1
    assert len(plan) == 5

    rwkv = configs.get("rwkv6-3b")
    assert all(m == "rwkv" for m, _ in rwkv.layer_plan())


def test_with_opts_validation():
    cfg = configs.get("internlm2-1.8b")
    c2 = cfg.with_opts(("fused_ce", "onehot_cache"))
    assert c2.opt_fused_ce and c2.opt_onehot_cache and not c2.opt_seq_parallel
    with pytest.raises(ValueError):
        cfg.with_opts(("not_a_real_opt",))


def test_reduced_configs_are_small():
    for name in configs.ARCHS:
        r = configs.get(name).reduced()
        assert r.params_count() < 5e7, name
        assert r.num_layers <= 16


def test_report_tables_render():
    from repro.launch.report import dryrun_table, perf_table, roofline_table
    d = dryrun_table()
    assert d.count("|") > 50
    r = roofline_table()
    assert "dominant" in r or "arch" in r
    perf_table()  # renders without error even if variants are sparse
