"""Tests for the repro.coded API redesign: scheme registry round-trips,
CodedMatmulConfig validation, CodedOp lifecycle, legacy-shim parity and
deprecation.  (The 8-device parity acceptance matrix lives in
spmd_coded_matmul_check.py; everything here runs on the default single
device.)"""

import dataclasses
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro.coded import (
    CodedMatmulConfig,
    from_plan,
    get_scheme,
    plan as plan_op,
    register_scheme,
    scheme_names,
)
from repro.core import schemes as schemes_lib
from repro.core.coded_matmul import coded_matmul, make_plan, uncoded_matmul_reference
from repro.core.decoder import DecodingError
from repro.sparse import dense_to_block_ell


def _mesh_1d(name="model"):
    return jax.make_mesh((len(jax.devices()),), (name,))


# ------------------------------ scheme registry ------------------------------

def test_every_core_scheme_is_registered():
    # every scheme in core/schemes.py is reachable by name via the registry
    assert set(schemes_lib.SCHEMES) == set(scheme_names())


@pytest.mark.parametrize("name", sorted(schemes_lib.SCHEMES))
def test_registry_roundtrip_builds_decodable_instance(name):
    m, n, N = 2, 3, 18
    sch = get_scheme(name)
    inst = sch.instance(m, n, None if name == "uncoded" else N, seed=0)
    workers = list(range(inst.num_workers))
    assert inst.can_decode(workers), f"{name}: not decodable with all workers"
    assert inst.mn == m * n


@pytest.mark.parametrize("name", ["uncoded", "sparse_code", "lt_code",
                                  "sparse_mds", "polynomial", "product"])
def test_registry_builds_device_plan_with_left_inverse_decode(name):
    m, n = 2, 2
    sch = get_scheme(name)
    p = sch.plan(m, n, None if name == "uncoded" else 12, seed=0)
    M = p.coefficient_matrix()
    assert np.linalg.matrix_rank(M) == m * n
    np.testing.assert_allclose(p.decode @ M, np.eye(m * n), atol=1e-4)


def test_mds_scheme_has_no_device_plan():
    # mds assigns n generator rows per worker: no one-row-per-device mapping
    with pytest.raises(ValueError, match="multiple generator rows"):
        get_scheme("mds").plan(2, 2, 8)
    assert not get_scheme("mds").device_capable(2, 2, 8)
    assert get_scheme("sparse_code").device_capable(2, 2, 8)


def test_host_and_device_share_one_design():
    # the plan's coefficient matrix IS the instance's generator matrix (up
    # to lockstep degree truncation) when built from the same seed -- the
    # silent-disagreement failure mode the registry exists to kill
    m, n, N, seed = 2, 3, 16, 4
    sch = get_scheme("sparse_code")
    p = sch.plan(m, n, N, seed=seed, max_degree=m * n)  # no truncation
    inst = sch.instance(m, n, N, seed=p.spec.seed)      # the accepted resample
    np.testing.assert_allclose(p.coefficient_matrix(), inst.M.toarray(),
                               atol=1e-6)


def test_unknown_scheme_rejected_with_known_names():
    with pytest.raises(ValueError, match="sparse_code"):
        get_scheme("nope")


def test_register_scheme_decorator_and_config_pickup():
    name = "_test_identity_code"
    try:
        @register_scheme(name, fixed_workers=True)
        def _identity(m, n):
            return schemes_lib.uncoded(m, n)

        assert name in scheme_names()
        cfg = CodedMatmulConfig(scheme=name)   # registry-validated
        op = plan_op(cfg, 1, 1).bind(_mesh_1d())
        A = jnp.asarray(np.ones((8, 4)), jnp.float32)
        B = jnp.asarray(np.ones((8, 4)), jnp.float32)
        np.testing.assert_allclose(np.asarray(op(A, B)),
                                   np.asarray(uncoded_matmul_reference(A, B)),
                                   atol=1e-5)
    finally:
        from repro.coded import registry as registry_mod
        registry_mod._REGISTRY.pop(name, None)


# ----------------------------- CodedMatmulConfig -----------------------------

def test_config_validates_against_registries_at_construction():
    with pytest.raises(ValueError, match="backend"):
        CodedMatmulConfig(backend="csr")
    with pytest.raises(ValueError, match="scheme"):
        CodedMatmulConfig(scheme="csr")
    with pytest.raises(ValueError, match="block_size"):
        CodedMatmulConfig(block_size=0)
    with pytest.raises(ValueError, match="axis_name"):
        CodedMatmulConfig(axis_name="")


def test_config_normalizes_dtype_spellings():
    for spelling in ("float32", np.float32, jnp.float32, "f4"):
        assert CodedMatmulConfig(out_dtype=spelling).out_dtype == "float32"
    assert CodedMatmulConfig(out_dtype=jnp.bfloat16).out_dtype == "bfloat16"
    # frozen + normalized => usable as a dict key / hashable
    assert hash(CodedMatmulConfig()) == hash(CodedMatmulConfig(out_dtype="f4"))


def test_config_rejects_float64_spellings():
    # the analysis dtype-policy pass would flag a staged f64 program; the
    # config rejects every spelling of it at construction instead
    for spelling in ("float64", np.float64, "f8", "double", float):
        with pytest.raises(ValueError, match="f32-accumulated"):
            CodedMatmulConfig(out_dtype=spelling)
    with pytest.raises(ValueError, match="f32-accumulated"):
        CodedMatmulConfig(out_dtype="complex128")
    # reduced-precision spellings stay legal
    for ok in ("float16", "bfloat16", "float32"):
        assert CodedMatmulConfig(out_dtype=ok).out_dtype == ok


# --------------------------------- CodedOp -----------------------------------

def test_op_lifecycle_unbound_then_bound():
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    op = from_plan(CodedMatmulConfig(), p)
    assert not op.bound
    A = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="unbound"):
        op(A, A)
    bound = op.bind(_mesh_1d())
    assert bound.bound and not op.bound  # frozen: bind returns a new op
    assert "workers=1" in repr(bound)


def test_op_bind_validates_axis():
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    with pytest.raises(ValueError, match="no axis"):
        from_plan(CodedMatmulConfig(axis_name="tp"), p).bind(_mesh_1d("model"))
    p9 = make_plan(2, 2, num_workers=9, seed=0)
    with pytest.raises(ValueError, match="workers"):
        from_plan(CodedMatmulConfig(), p9).bind(_mesh_1d())


def test_op_with_survivors_raises_eagerly_and_resets():
    p = make_plan(2, 2, num_workers=6, seed=1)
    op = from_plan(CodedMatmulConfig(), p)
    with pytest.raises(DecodingError, match="rank"):
        op.with_survivors(np.zeros(6, dtype=bool))   # at rebind, not apply
    # all-alive mask and None both restore the base plan
    assert op.with_survivors(np.ones(6, dtype=bool)).plan_ is p
    assert op.with_survivors(None).plan_ is p


def test_op_strict_about_pack_operands():
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    op = from_plan(CodedMatmulConfig(backend="dense_scan"), p).bind(_mesh_1d())
    A = jnp.zeros((8, 8), jnp.float32)
    ell = dense_to_block_ell(np.zeros((8, 8), np.float32), block_size=8)
    with pytest.raises(ValueError, match="takes no a_sparse/pack"):
        op(A, A, a_sparse=ell)


def test_op_consults_runtime_pack_cache():
    from repro.runtime import pack_cache

    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    rng = np.random.default_rng(0)
    A_np = rng.standard_normal((16, 8)).astype(np.float32)
    A = jnp.asarray(A_np)
    B = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    ell = dense_to_block_ell(A_np, block_size=8)
    op = from_plan(CodedMatmulConfig(backend="block_sparse"), p).bind(_mesh_1d())
    pack_cache.clear()
    op(A, B, a_sparse=ell)
    op(A, B, a_sparse=ell)
    stats = pack_cache.cache_stats()
    assert stats["misses"] == 1 and stats["hits"] >= 1
    # survivor rebinds reuse the same pack (keyed on the base plan)
    op.with_survivors(np.ones(p.num_workers, dtype=bool))(A, B, a_sparse=ell)
    assert pack_cache.cache_stats()["misses"] == 1
    pack_cache.clear()


def test_out_dtype_flows_through_op():
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    op = from_plan(CodedMatmulConfig(out_dtype="bfloat16"), p).bind(_mesh_1d())
    A = jnp.asarray(np.ones((8, 4)), jnp.float32)
    assert op(A, A).dtype == jnp.bfloat16


# ------------------------- legacy shim: parity + warning ---------------------

def test_legacy_coded_matmul_emits_deprecation_warning():
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    A = jnp.asarray(np.ones((8, 4)), jnp.float32)
    with pytest.deprecated_call(match="repro.coded"):
        coded_matmul(A, A, p, _mesh_1d())


@pytest.mark.parametrize("backend", ["dense_scan", "block_sparse"])
@pytest.mark.parametrize("out_sharded", [False, True])
def test_old_new_bit_parity_single_device(backend, out_sharded):
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    mesh = _mesh_1d()
    rng = np.random.default_rng(7)
    A_np = rng.standard_normal((24, 16)).astype(np.float32)
    A = jnp.asarray(A_np)
    B = jnp.asarray(rng.standard_normal((24, 12)), jnp.float32)
    ell = dense_to_block_ell(A_np, block_size=8)
    kw = {"a_sparse": ell} if backend == "block_sparse" else {}
    op = from_plan(CodedMatmulConfig(backend=backend, out_sharded=out_sharded),
                   p).bind(mesh)
    C_new = op(A, B, **kw)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        C_old = coded_matmul(A, B, p, mesh, backend=backend,
                             out_sharded=out_sharded, **kw)
    np.testing.assert_array_equal(np.asarray(C_new), np.asarray(C_old))


# ----------------------- auto backend + quantization -------------------------

def _density_ell(density, RB=16, CB=2, bs=8, seed=0):
    """A BlockELL with a controlled live-tile fraction."""
    rng = np.random.default_rng(seed)
    mask = rng.random((RB, CB)) < density
    mask[0, 0] = density > 0  # at least one live tile when density > 0
    A = rng.standard_normal((RB * bs, CB * bs)) * np.kron(mask, np.ones((bs, bs)))
    return A.astype(np.float32), dense_to_block_ell(A.astype(np.float32),
                                                    block_size=bs)


@pytest.mark.parametrize("density,expect", [
    (0.02, "block_sparse"),   # 2%: far under the 0.25 default threshold
    (0.30, "dense_scan"),     # 30%: above it
])
def test_auto_backend_picks_by_measured_density(density, expect):
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    cfg = CodedMatmulConfig(backend="auto")
    op = from_plan(cfg, p).bind(_mesh_1d())
    A_np, ell = _density_ell(density)
    assert abs(ell.density() - density) < 0.15
    chosen, frac, _ = op._auto_backend(None, ell, None, A_np.shape[0])
    assert chosen == expect and abs(frac - ell.density()) < 1e-9
    # and end-to-end through apply: correct numbers either way
    A = jnp.asarray(A_np)
    B = jnp.asarray(np.random.default_rng(1).standard_normal(
        (A_np.shape[0], 12)), jnp.float32)
    C = op.apply(A, B, a_sparse=ell)
    np.testing.assert_allclose(
        np.asarray(C), np.asarray(uncoded_matmul_reference(A, B)),
        atol=5e-2, rtol=1e-3)


def test_auto_backend_threshold_is_configurable():
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    _, ell = _density_ell(0.30)
    mesh = _mesh_1d()
    loose = from_plan(CodedMatmulConfig(backend="auto",
                                        auto_density_threshold=0.9), p).bind(mesh)
    assert loose._auto_backend(None, ell, None, 128)[0] == "block_sparse"
    tight = from_plan(CodedMatmulConfig(backend="auto",
                                        auto_density_threshold=0.01), p).bind(mesh)
    assert tight._auto_backend(None, ell, None, 128)[0] == "dense_scan"
    with pytest.raises(ValueError, match="auto_density_threshold"):
        CodedMatmulConfig(backend="auto", auto_density_threshold=1.5)


def test_auto_backend_concrete_A_and_tracer_rejection():
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    op = from_plan(CodedMatmulConfig(backend="auto"), p).bind(_mesh_1d())
    A_np, _ = _density_ell(0.02)
    # concrete A: density measured by packing it on the spot
    chosen, frac, ell = op._auto_backend(jnp.asarray(A_np), None, None,
                                         A_np.shape[0])
    assert chosen == "block_sparse" and ell is not None
    # traced A with no density side-channel: loud error, not a silent guess
    with pytest.raises(ValueError, match="auto.*under jit|jit needs"):
        jax.jit(lambda a: op._auto_backend(a, None, None, 128))(
            jnp.asarray(A_np))


def test_auto_backend_is_virtual_everywhere_below_the_api():
    from repro.core import coded_backends
    from repro.core.coded_matmul import stage_coded_matmul

    assert coded_backends.get_backend("auto").virtual
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    A = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="pseudo-backend"):
        stage_coded_matmul(A, A, p, _mesh_1d(), backend="auto")


def test_compute_dtype_validation_and_cond_budget():
    # unknown dtype and pack-free backend both rejected at construction
    with pytest.raises(ValueError, match="compute_dtype"):
        CodedMatmulConfig(compute_dtype="fp8")
    with pytest.raises(ValueError, match="needs_pack|pack"):
        CodedMatmulConfig(backend="dense_scan", compute_dtype="int8")
    # sparse_code (cond_warn=1e8): eps * cond within the 1e6 budget
    CodedMatmulConfig(scheme="sparse_code", backend="block_sparse",
                      compute_dtype="int8")
    CodedMatmulConfig(scheme="sparse_code", backend="block_sparse",
                      compute_dtype="bfloat16")
    # product (cond_warn=1e11): quantization noise can amplify past budget
    for dt in ("int8", "bfloat16"):
        with pytest.raises(ValueError, match="product.*budget|budget.*product"):
            CodedMatmulConfig(scheme="product", backend="block_sparse",
                              compute_dtype=dt)


def test_quantized_pack_layout_and_cache_key():
    from repro.core.coded_matmul import pack_worker_tiles
    from repro.runtime import pack_cache

    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    A_np, ell = _density_ell(0.3, seed=5)
    pk8 = pack_worker_tiles(ell, p, compute_dtype="int8")
    assert pk8.vals.dtype == np.int8 and pk8.compute_dtype == "int8"
    assert pk8.tile_scale is not None
    assert pk8.tile_scale.shape == pk8.vals.shape[:-2]
    pk32 = pack_worker_tiles(ell, p)
    deq = pk8.vals.astype(np.float32) * pk8.tile_scale[..., None, None]
    amax = np.abs(pk32.vals).max()
    assert np.abs(deq - pk32.vals).max() <= amax / 127.0 + 1e-6
    pkbf = pack_worker_tiles(ell, p, compute_dtype="bfloat16")
    assert pkbf.vals.dtype.itemsize == 2 and pkbf.tile_scale is None
    with pytest.raises(ValueError, match="compute_dtype"):
        pack_worker_tiles(ell, p, compute_dtype="fp4")
    # the runtime cache keys on dtype: same (ell, plan) pair, two entries
    pack_cache.clear()
    pack_cache.get_pack(ell, p)
    pack_cache.get_pack(ell, p, compute_dtype="int8")
    pack_cache.get_pack(ell, p, compute_dtype="int8")
    st = pack_cache.cache_stats()
    assert st["misses"] == 2 and st["hits"] == 1
    pack_cache.clear()


@pytest.mark.parametrize("dtype,tol", [("bfloat16", 2e-2), ("int8", 2e-2)])
def test_quantized_coded_matmul_end_to_end(dtype, tol):
    """Quantized block_sparse apply stays within the declared dtype
    tolerance of the f32 result on well-conditioned data."""
    p = make_plan(1, 1, num_workers=len(jax.devices()), max_degree=1, seed=3)
    rng = np.random.default_rng(2)
    A_np, ell = _density_ell(0.4, seed=2)
    A = jnp.asarray(A_np)
    B = jnp.asarray(rng.standard_normal((A_np.shape[0], 12)), jnp.float32)
    mesh = _mesh_1d()
    C32 = from_plan(CodedMatmulConfig(backend="block_sparse"), p).bind(
        mesh).apply(A, B, a_sparse=ell)
    Cq = from_plan(CodedMatmulConfig(backend="block_sparse",
                                     compute_dtype=dtype), p).bind(
        mesh).apply(A, B, a_sparse=ell)
    scale = float(np.abs(np.asarray(C32)).max())
    np.testing.assert_allclose(np.asarray(Cq), np.asarray(C32),
                               atol=tol * scale, rtol=tol)
    # a stale f32 pack is rejected when the config asks for int8
    from repro.core.coded_matmul import pack_worker_tiles

    with pytest.raises(ValueError, match="compute_dtype"):
        from_plan(CodedMatmulConfig(backend="block_sparse",
                                    compute_dtype=dtype), p).bind(mesh).apply(
            A, B, pack=pack_worker_tiles(ell, p))


# ------------------------------ package surface ------------------------------

def test_top_level_exports():
    assert repro.CodedMatmulConfig is CodedMatmulConfig
    assert repro.get_scheme is get_scheme
    assert callable(repro.CodedOp)
    assert callable(repro.run_device_job)
    for name in repro.__all__:
        assert getattr(repro, name) is not None
