import numpy as np
import pytest

from repro.core import degree as dg


@pytest.mark.parametrize("d", [3, 4, 6, 16, 100, 1024])
def test_wave_soliton_normalized(d):
    p = dg.wave_soliton(d)
    assert p.shape == (d,)
    assert np.all(p >= 0)
    assert np.isclose(p.sum(), 1.0, atol=1e-12)


def test_wave_soliton_matches_paper_form():
    d = 64
    p = dg.wave_soliton(d)
    tau = dg.WAVE_TAU
    # Analytic normalization is exactly 1, so entries match eq. (7) directly.
    assert np.isclose(p[0], tau / d, rtol=1e-9)
    assert np.isclose(p[1], tau / 70.0, rtol=1e-9)
    for k in (3, 10, 64):
        assert np.isclose(p[k - 1], tau / (k * (k - 1)), rtol=1e-9)


def test_wave_soliton_average_degree_is_log(  ):
    # E[X] = Theta(tau ln d)  (Lemma 4)
    for d in (64, 256, 1024):
        avg = dg.average_degree(dg.wave_soliton(d))
        assert 0.5 * np.log(d) < avg < 3.0 * np.log(d)


@pytest.mark.parametrize("name", ["wave_soliton", "ideal_soliton", "robust_soliton", "optimized"])
@pytest.mark.parametrize("d", [6, 16, 40])
def test_all_distributions_valid(name, d):
    p = dg.get_distribution(name, d)
    assert p.shape == (d,)
    assert np.isclose(p.sum(), 1.0)
    assert np.all(p >= -1e-15)


def test_table_iv_loaded():
    for d in (6, 9, 12, 16, 25):
        p = dg.optimized_distribution(d)
        assert np.isclose(p.sum(), 1.0)
        # Table IV average degrees: 2.01, 2.21, 2.78, 2.98, 3.54
        expected = {6: 2.01, 9: 2.21, 12: 2.78, 16: 2.98, 25: 3.54}[d]
        assert abs(dg.average_degree(p) - expected) < 0.05


def test_sampling_bounds():
    rng = np.random.default_rng(0)
    p = dg.wave_soliton(32)
    s = dg.sample_degrees(rng, p, 1000)
    assert s.min() >= 1 and s.max() <= 32


def test_generator_poly_derivative_consistent():
    p = dg.wave_soliton(16)
    xs = np.linspace(0.05, 0.95, 7)
    eps = 1e-6
    num = (dg.degree_generator_poly(p, xs + eps) - dg.degree_generator_poly(p, xs - eps)) / (2 * eps)
    ana = dg.degree_generator_dpoly(p, xs)
    np.testing.assert_allclose(num, ana, rtol=1e-5)


def test_unknown_distribution_raises():
    with pytest.raises(ValueError):
        dg.get_distribution("nope", 8)
