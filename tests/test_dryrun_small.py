"""Dry-run machinery on a small 8-device mesh (subprocess keeps the main
pytest process single-device).  Covers: every arch family lowers+compiles a
train step and a decode step with explicit shardings; collective parsing and
memory analysis produce sane numbers; the multi-pod 'pod' axis shards."""

import json
import os
import pathlib
import subprocess
import sys

import pytest

SRC = str(pathlib.Path(__file__).parents[1] / "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")
import json
import dataclasses
import jax
import repro.configs as configs
from repro import compat
from repro.launch import meshctx
from repro.launch.dryrun import build_cell, collective_bytes, SHAPES

ARCHS = ["internlm2-1.8b", "qwen3-moe-30b-a3b", "rwkv6-3b",
         "jamba-1.5-large-398b", "whisper-medium", "llama-3.2-vision-11b"]

def tiny(cfg):
    g = cfg.group_size
    kw = dict(num_layers=g, d_model=64, num_heads=4, num_kv_heads=2,
              head_dim=16, d_ff=128, vocab_size=512, max_seq=64)
    if cfg.moe:
        kw["moe"] = dataclasses.replace(cfg.moe, num_experts=4, top_k=2, d_ff=32)
    if cfg.encoder_layers:
        kw["encoder_layers"] = 1
        kw["encoder_seq"] = 16
    if cfg.cross_attn_every:
        kw["vision_tokens"] = 16
    if cfg.rwkv:
        kw["rwkv_head_size"] = 16
    return dataclasses.replace(cfg, **kw)

SHAPES["tiny_train"] = dict(seq=32, batch=8, kind="train")
SHAPES["tiny_decode"] = dict(seq=32, batch=8, kind="decode")

out = {}
for multi in (False, True):
    # same dp-total (4) and tp (2) on both meshes: the multi mesh only
    # re-labels half the data parallelism as the 'pod' axis
    shape = (2, 2, 2) if multi else (4, 2)
    axes = ("pod", "data", "model") if multi else ("data", "model")
    mesh = compat.make_mesh(shape, axes,
                            axis_types=compat.auto_axis_types(len(axes)))
    for arch in ARCHS:
        cfg = tiny(configs.get(arch))
        for shp in ("tiny_train", "tiny_decode"):
            with meshctx.use_mesh(mesh):
                fn, args, in_sh, out_sh = build_cell(cfg, shp, mesh)
                compiled = jax.jit(fn, in_shardings=in_sh,
                                   out_shardings=out_sh).lower(*args).compile()
            ca = compat.cost_analysis(compiled)
            coll = collective_bytes(compiled.as_text())
            key = f"{arch}/{shp}/{'multi' if multi else 'single'}"
            out[key] = {"flops": float(ca.get("flops", -1)),
                        "coll": coll["total_bytes"]}
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dryrun_results():
    env = dict(os.environ, PYTHONPATH=SRC, TF_CPP_MIN_LOG_LEVEL="2")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_all_families_compile_on_both_meshes(dryrun_results):
    assert len(dryrun_results) == 6 * 2 * 2
    for key, rec in dryrun_results.items():
        assert rec["flops"] > 0, key


def test_training_has_collectives(dryrun_results):
    # sharded training must communicate: every train cell shows collectives
    for key, rec in dryrun_results.items():
        if "tiny_train" in key:
            assert rec["coll"] > 0, key


def test_multi_pod_shards_the_pod_axis(dryrun_results):
    # the (pod, data) product equals the single mesh's data axis, so
    # per-device flops must agree within compiler noise -- proving the pod
    # axis genuinely carries its share of the batch
    for arch in ("internlm2-1.8b", "qwen3-moe-30b-a3b"):
        s = dryrun_results[f"{arch}/tiny_train/single"]["flops"]
        m = dryrun_results[f"{arch}/tiny_train/multi"]["flops"]
        assert 0.7 < m / s < 1.4, (arch, s, m)
