"""Equivalence of the vectorized host-packing paths against reference loops.

``pack_worker_tiles`` and ``dense_to_block_ell`` are NumPy bucketed/argsort
rewrites of what used to be pure-Python nested loops; these tests keep the
loop implementations alive as oracles and assert exact (bit-for-bit) layout
equality across plans, shapes, and densities."""

import numpy as np
import pytest

from repro.core.coded_matmul import make_plan, pack_worker_tiles
from repro.runtime import pack_cache
from repro.sparse import BlockELL, block_ell_to_dense, dense_to_block_ell


# ----------------------- reference implementations -------------------------

def _dense_to_block_ell_ref(A, block_size=8, slots=None):
    """The pre-vectorization per-column-block loop, kept as the oracle."""
    rows, cols = A.shape
    bs = block_size
    RB, CB = rows // bs, cols // bs
    tiles = A.reshape(RB, bs, CB, bs).transpose(2, 0, 1, 3)
    live = np.abs(tiles).sum(axis=(2, 3)) > 0
    per_cb = live.sum(axis=1)
    L = int(slots if slots is not None else max(int(per_cb.max(initial=1)), 1))
    vals = np.zeros((CB, L, bs, bs), dtype=A.dtype)
    idx = np.zeros((CB, L), dtype=np.int32)
    nnzb = np.zeros((CB,), dtype=np.int32)
    for cb in range(CB):
        rbs = np.flatnonzero(live[cb])
        if len(rbs) > L:  # keep largest-energy tiles
            energy = np.abs(tiles[cb, rbs]).sum(axis=(1, 2))
            rbs = rbs[np.argsort(-energy)[:L]]
            rbs.sort()
        take = len(rbs)
        vals[cb, :take] = tiles[cb, rbs]
        idx[cb, :take] = rbs
        nnzb[cb] = take
    return BlockELL(vals=vals, idx=idx, nnzb=nnzb, shape=(rows, cols),
                    block_size=bs)


def _pack_worker_tiles_ref(ell, plan):
    """Nested-loop packing in the fused-gather layout, kept as the oracle."""
    s, r = ell.shape
    bs = ell.block_size
    m, n = plan.m, plan.n
    br = r // m
    CBl = br // bs
    N, L = plan.cols.shape
    per = [[[] for _ in range(CBl)] for _ in range(N)]
    for k in range(N):
        for l in range(L):
            if plan.weights[k, l] == 0.0:
                continue
            i, j = divmod(int(plan.cols[k, l]), n)
            for cb in range(CBl):
                g = i * CBl + cb
                for e in range(int(ell.nnzb[g])):
                    per[k][cb].append((int(ell.idx[g, e]), j,
                                       float(plan.weights[k, l]), l,
                                       ell.vals[g, e]))
    Lw = max(1, max((len(per[k][cb]) for k in range(N) for cb in range(CBl)),
                    default=1))
    vals = np.zeros((N, CBl, Lw, bs, bs), np.float32)
    src = np.zeros((N, CBl, Lw, 2), np.int32)
    wslot = np.zeros((N, CBl, Lw), np.float32)
    slot_of = np.zeros((N, CBl, Lw), np.int32)
    live = np.zeros((N,), np.int64)
    for k in range(N):
        for cb in range(CBl):
            for slot, (rb, j, w, l, tile) in enumerate(per[k][cb]):
                vals[k, cb, slot] = tile
                src[k, cb, slot] = (rb, j)
                wslot[k, cb, slot] = w
                slot_of[k, cb, slot] = l
            live[k] += len(per[k][cb])
    return vals, src, wslot, slot_of, live


# --------------------------------- tests -----------------------------------

@pytest.mark.parametrize("bs,RB,CB,density,slots", [
    (8, 6, 4, 0.3, None),
    (8, 4, 4, 0.0, None),      # all-dead matrix
    (16, 3, 5, 1.0, None),     # fully dense
    (8, 8, 3, 0.6, 4),         # truncating slots: top-energy selection
    (4, 2, 3, 0.5, 5),         # slots > live tiles: padding
    (8, 2, 2, 0.9, 6),         # slots > RB: sentinel padding path
])
def test_dense_to_block_ell_matches_reference(bs, RB, CB, density, slots):
    rng = np.random.default_rng(hash((bs, RB, CB, slots)) % 2**31)
    mask = rng.random((RB, CB)) < density
    A = rng.standard_normal((RB * bs, CB * bs)) * np.kron(mask, np.ones((bs, bs)))
    got = dense_to_block_ell(A, block_size=bs, slots=slots)
    want = _dense_to_block_ell_ref(A, block_size=bs, slots=slots)
    np.testing.assert_array_equal(got.idx, want.idx)
    np.testing.assert_array_equal(got.nnzb, want.nnzb)
    np.testing.assert_array_equal(got.vals, want.vals)
    assert got.shape == want.shape and got.block_size == want.block_size
    if slots is None:
        np.testing.assert_array_equal(block_ell_to_dense(got), A)


@pytest.mark.parametrize("m,n,workers,s,bs,density", [
    (2, 2, 8, 32, 8, 0.4),
    (2, 3, 10, 48, 8, 0.15),
    (4, 2, 12, 32, 16, 0.7),
    (1, 1, 4, 16, 8, 0.0),     # empty operand: zero live tiles everywhere
])
def test_pack_worker_tiles_matches_reference(m, n, workers, s, bs, density):
    rng = np.random.default_rng(hash((m, n, workers, s, bs)) % 2**31)
    plan = make_plan(m, n, num_workers=workers, seed=7)
    r = m * 2 * bs  # two column blocks per worker row-block
    mask = rng.random((s // bs, r // bs)) < density
    A = rng.standard_normal((s, r)) * np.kron(mask, np.ones((bs, bs)))
    ell = dense_to_block_ell(A.astype(np.float32), block_size=bs)
    got = pack_worker_tiles(ell, plan)
    vals, src, wslot, slot_of, live = _pack_worker_tiles_ref(ell, plan)
    np.testing.assert_array_equal(got.vals, vals)
    np.testing.assert_array_equal(got.src, src)
    np.testing.assert_array_equal(got.wslot, wslot)
    np.testing.assert_array_equal(got.slot_of, slot_of)
    np.testing.assert_array_equal(got.live_tiles, live)
    assert got.block_size == bs
    # slot_of round-trips the pack's weights through the plan's task table
    # (the gather the chunk-masked local product performs on device)
    regather = plan.weights[np.arange(plan.cols.shape[0])[:, None, None],
                            got.slot_of] * (got.wslot != 0.0)
    np.testing.assert_array_equal(regather.astype(np.float32), got.wslot)


def test_pack_cache_identity_keyed_lru():
    plan = make_plan(2, 2, num_workers=8, seed=0)
    rng = np.random.default_rng(3)
    A = rng.standard_normal((32, 32)).astype(np.float32)
    ell = dense_to_block_ell(A, block_size=8)
    pack_cache.clear()
    p1 = pack_cache.get_pack(ell, plan)
    p2 = pack_cache.get_pack(ell, plan)
    assert p1 is p2, "same (ell, plan) objects must hit the cache"
    stats = pack_cache.cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1
    # a distinct (equal-valued) BlockELL is a different key: no false sharing
    ell2 = dense_to_block_ell(A, block_size=8)
    p3 = pack_cache.get_pack(ell2, plan)
    assert p3 is not p1
    np.testing.assert_array_equal(p3.vals, p1.vals)
    pack_cache.clear()
    assert pack_cache.cache_stats() == {
        "entries": 0, "hits": 0, "misses": 0, "evictions": 0}


def test_pack_cache_eviction_counter():
    rng = np.random.default_rng(5)
    cache = pack_cache.PackCache(max_entries=2)
    plan = make_plan(2, 2, num_workers=8, seed=0)
    ells = [dense_to_block_ell(rng.standard_normal((32, 32)).astype(np.float32),
                               block_size=8) for _ in range(3)]
    for ell in ells:
        cache.get_pack(ell, plan)
    stats = cache.stats()
    assert stats == {"entries": 2, "hits": 0, "misses": 3, "evictions": 1}
    # the evicted (oldest) entry re-packs: a miss, not a stale hit
    cache.get_pack(ells[0], plan)
    assert cache.stats()["misses"] == 4 and cache.stats()["evictions"] == 2
