"""Property-based tests (hypothesis) for the sparse code's invariants.

Invariants:
  P1  decode(encode(blocks)) == blocks for ANY full-rank collected subset,
      any (m, n), any degree distribution, any weight set.
  P2  hybrid decode == Gaussian-elimination oracle on the same rows.
  P3  the structural schedule replays correctly on fresh data (schedule is
      data-independent).
  P4  decode cost scales with nnz: axpy count <= nnz(M) and every op touches
      exactly one block.
  P5  integer inputs + integer weights => bit-exact recovery (no float drift
      through peeling).
"""

import numpy as np
import pytest
import scipy.sparse as sp

# optional test dependency (requirements-test.txt): every test here is a
# hypothesis property, so skip the whole module -- never fail collection
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import (
    SparseCodeSpec,
    generate_coefficient_matrix,
    make_tasks,
    encode_blocks,
    hybrid_decode,
    gaussian_decode,
    peel_schedule,
    apply_schedule,
)
from repro.core.encoder import split_blocks

SETTINGS = dict(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def code_instances(draw):
    m = draw(st.integers(1, 4))
    n = draw(st.integers(1, 4))
    d = m * n
    extra = draw(st.integers(2, 8))
    dist = draw(st.sampled_from(["wave_soliton", "robust_soliton", "optimized"]))
    wkind = draw(st.sampled_from(["paper", "symmetric"]))
    seed = draw(st.integers(0, 10_000))
    spec = SparseCodeSpec(m=m, n=n, num_workers=d + extra,
                          distribution=dist, weight_kind=wkind, seed=seed)
    return spec


@given(spec=code_instances(), data=st.data())
@settings(**SETTINGS)
def test_p1_p2_decode_inverts_encode_any_full_rank_subset(spec, data):
    rng = np.random.default_rng(spec.seed + 1)
    M = generate_coefficient_matrix(spec)
    d = spec.mn
    blocks_true = [np.round(rng.random((3, 4)) * 8) for _ in range(d)]
    Md = M.toarray()
    results = [
        sum(Md[r, c] * blocks_true[c] for c in range(d) if Md[r, c] != 0.0)
        if Md[r].any() else np.zeros((3, 4))
        for r in range(M.shape[0])
    ]
    # random subset containing at least mn rows
    k = data.draw(st.integers(d, M.shape[0]))
    rows = sorted(rng.choice(M.shape[0], size=k, replace=False).tolist())
    sub = M[rows]
    if np.linalg.matrix_rank(sub.toarray()) < d:
        return  # not decodable; nothing to assert (P1 is about full-rank sets)
    data_rows = [results[r] for r in rows]
    got, stats = hybrid_decode(sub, data_rows)
    for g, t in zip(got, blocks_true):
        np.testing.assert_allclose(g, t, atol=1e-5)
    oracle = gaussian_decode(sub, data_rows)
    for g, o in zip(got, oracle):
        np.testing.assert_allclose(g, o, atol=1e-5)
    assert stats.peels + stats.roots == d


@given(spec=code_instances())
@settings(**SETTINGS)
def test_p3_schedule_data_independence(spec):
    rng = np.random.default_rng(spec.seed + 2)
    M = generate_coefficient_matrix(spec)
    d = spec.mn
    if np.linalg.matrix_rank(M.toarray()) < d:
        return
    sched, _ = peel_schedule(M)
    for trial in range(2):
        blocks_true = [rng.standard_normal((2, 3)) for _ in range(d)]
        Md = M.toarray()
        results = [
            sum(Md[r, c] * blocks_true[c] for c in range(d) if Md[r, c] != 0.0)
            if Md[r].any() else np.zeros((2, 3))
            for r in range(M.shape[0])
        ]
        got = apply_schedule(sched, results)
        for g, t in zip(got, blocks_true):
            np.testing.assert_allclose(g, t, atol=1e-6)


@given(spec=code_instances())
@settings(**SETTINGS)
def test_p4_axpy_count_bounded_by_nnz(spec):
    M = generate_coefficient_matrix(spec)
    if np.linalg.matrix_rank(M.toarray()) < spec.mn:
        return
    sched, stats = peel_schedule(M)
    # every nonzero of M is consumed by at most one axpy or one peel/root
    assert stats.axpys <= M.nnz
    assert stats.peels + stats.roots == spec.mn


@given(st.integers(0, 5000))
@settings(**SETTINGS)
def test_p5_integer_exactness(seed):
    """Integer matrices + integer weights decode bit-exactly through peeling."""
    rng = np.random.default_rng(seed)
    m = n = 2
    spec = SparseCodeSpec(m=m, n=n, num_workers=10, seed=seed)
    M = generate_coefficient_matrix(spec)
    if np.linalg.matrix_rank(M.toarray()) < 4:
        return
    A = rng.integers(0, 4, size=(20, 8)).astype(np.float64)
    B = rng.integers(0, 4, size=(20, 12)).astype(np.float64)
    A_blocks = split_blocks(A, m)
    B_blocks = split_blocks(B, n)
    results = [encode_blocks(t, A_blocks, B_blocks, n) for t in make_tasks(M)]
    got, stats = hybrid_decode(M, results)
    C = A.T @ B
    br, bt = C.shape[0] // m, C.shape[1] // n
    for i in range(m):
        for j in range(n):
            want = C[i * br:(i + 1) * br, j * bt:(j + 1) * bt]
            if stats.roots == 0:
                # pure peeling on integers: exact to the bit
                np.testing.assert_array_equal(got[i * n + j], want)
            else:
                np.testing.assert_allclose(got[i * n + j], want, atol=1e-6)
