import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.coded import CodedMatmulConfig, from_plan
from repro.core.coded_matmul import (
    BACKENDS,
    CodedMatmulPlan,
    _largest_tile,
    make_plan,
    pack_worker_tiles,
    uncoded_matmul_reference,
)
from repro.core.decoder import DecodingError
from repro.sparse import dense_to_block_ell


def _bound_op(plan, mesh, **cfg_kw):
    return from_plan(CodedMatmulConfig(**cfg_kw), plan).bind(mesh)


def _mesh_1d(name="model"):
    devs = jax.devices()
    return jax.make_mesh((len(devs),), (name,))


def test_make_plan_full_rank_and_padded():
    plan = make_plan(2, 2, num_workers=8, seed=0)
    assert plan.cols.shape == plan.weights.shape == (8, plan.max_degree)
    M = np.zeros((8, 4))
    for k in range(8):
        for l in range(plan.max_degree):
            if plan.weights[k, l] != 0:
                M[k, plan.cols[k, l]] += plan.weights[k, l]
    assert np.linalg.matrix_rank(M) == 4
    # decode really is a left inverse
    np.testing.assert_allclose(plan.decode @ M, np.eye(4), atol=1e-4)


def test_coded_matmul_single_device_mn1():
    # on the single default device only mn=1 is codable (N=1 row spans 1 block)
    mesh = _mesh_1d()
    plan = make_plan(1, 1, num_workers=mesh.shape["model"], max_degree=1, seed=3)
    rng = np.random.default_rng(0)
    s, r, t = 24, 8, 12
    A = jnp.asarray(rng.standard_normal((s, r)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
    C = _bound_op(plan, mesh)(A, B)
    C_ref = uncoded_matmul_reference(A, B)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref), atol=1e-2, rtol=1e-3)


def test_coded_matmul_spmd_8dev_subprocess():
    """Full SPMD check on an 8-device mesh (subprocess so the main pytest
    process keeps the default single-device platform)."""
    import pathlib
    import subprocess
    import sys

    script = pathlib.Path(__file__).parent / "spmd_coded_matmul_check.py"
    env = dict(os.environ, PYTHONPATH=str(pathlib.Path(__file__).parents[1] / "src"))
    # the check grew the partial-chunk survivor axis (extra shard_map
    # compilations per plan), so give it headroom beyond the historical 600
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ALL-OK" in out.stdout


def test_coded_matmul_single_device_block_sparse():
    # the block_sparse backend must agree with dense_scan on the trivial
    # single-device code too (mn=1, one worker, bs=8 tiles)
    mesh = _mesh_1d()
    plan = make_plan(1, 1, num_workers=mesh.shape["model"], max_degree=1, seed=3)
    rng = np.random.default_rng(1)
    s, r, t = 24, 16, 12
    A_np = rng.standard_normal((s, r))
    A_np[:, 8:] = 0.0  # one dead column tile column: block sparsity is real
    A = jnp.asarray(A_np, jnp.float32)
    B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
    C = _bound_op(plan, mesh, backend="block_sparse")(A, B)
    C_ref = uncoded_matmul_reference(A, B)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref), atol=1e-2, rtol=1e-3)


def test_coded_matmul_out_sharded_matches_replicated_single_device():
    # the scatter decode must agree with the replicated decode bit-for-bit
    # (the 8-device + dead-worker variants live in spmd_coded_matmul_check)
    mesh = _mesh_1d()
    plan = make_plan(1, 1, num_workers=mesh.shape["model"], max_degree=1, seed=3)
    rng = np.random.default_rng(2)
    s, r, t = 24, 16, 12
    A = jnp.asarray(rng.standard_normal((s, r)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
    for backend in BACKENDS:
        C_rep = _bound_op(plan, mesh, backend=backend)(A, B)
        C_sc = _bound_op(plan, mesh, backend=backend, out_sharded=True)(A, B)
        np.testing.assert_array_equal(np.asarray(C_sc), np.asarray(C_rep))


def test_coded_matmul_accepts_prebuilt_pack():
    # a pack built once (e.g. by the runtime LRU cache) short-circuits
    # re-packing and produces the same result as the a_sparse path
    mesh = _mesh_1d()
    plan = make_plan(1, 1, num_workers=mesh.shape["model"], max_degree=1, seed=3)
    rng = np.random.default_rng(4)
    s, r, t = 32, 16, 12
    A_np = rng.standard_normal((s, r)).astype(np.float32)
    A = jnp.asarray(A_np)
    B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
    ell = dense_to_block_ell(A_np, block_size=8)
    pack = pack_worker_tiles(ell, plan)
    op = _bound_op(plan, mesh, backend="block_sparse")
    C_pack = op(A, B, pack=pack)
    C_ell = op(A, B, a_sparse=ell)
    np.testing.assert_array_equal(np.asarray(C_pack), np.asarray(C_ell))


def test_coded_matmul_rejects_stale_pack():
    # a pack built for a different A must be refused, not silently gathered
    # out of range (XLA clamps indices, which would corrupt the result)
    mesh = _mesh_1d()
    plan = make_plan(1, 1, num_workers=mesh.shape["model"], max_degree=1, seed=3)
    rng = np.random.default_rng(5)
    A_big = rng.standard_normal((64, 16)).astype(np.float32)
    pack = pack_worker_tiles(dense_to_block_ell(A_big, block_size=8), plan)
    A = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)   # shorter s
    B = jnp.asarray(rng.standard_normal((32, 12)), jnp.float32)
    op = _bound_op(plan, mesh, backend="block_sparse")
    with pytest.raises(ValueError, match="different A"):
        op(A, B, pack=pack)
    # wrong output tiling (r mismatch) is also refused
    A2 = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    B2 = jnp.asarray(rng.standard_normal((64, 12)), jnp.float32)
    with pytest.raises(ValueError, match="does not tile"):
        op(A2, B2, pack=pack)


def test_coded_matmul_rejects_unknown_backend():
    # the config is the validation point now: an unknown backend never
    # reaches staging (and the registry snapshot still lists the builtins)
    with pytest.raises(ValueError, match="backend"):
        CodedMatmulConfig(backend="nope")
    assert set(BACKENDS) == {"dense_scan", "block_sparse", "auto"}


def test_largest_tile_picks_biggest_divisor_capped():
    # the kernel tile width is the largest divisor of bt <= 128 -- never a
    # degenerate whole-row tile when a proper divisor exists
    assert _largest_tile(256) == 128
    assert _largest_tile(128) == 128
    assert _largest_tile(192) == 96   # old code would have fallen back to 192
    assert _largest_tile(24) == 24
    assert _largest_tile(130) == 65   # 65 divides 130 and is <= 128
    assert _largest_tile(127) == 127  # prime <= 128: the row itself
    assert _largest_tile(1) == 1


def test_pack_worker_tiles_counts_live_tiles():
    # packing is nnz-proportional: an all-zero A packs zero live tiles, a
    # dense A packs (live blocks of A) x (slots with nonzero weight)
    plan = make_plan(2, 2, num_workers=8, seed=0)
    s, r = 16, 16
    ell0 = dense_to_block_ell(np.zeros((s, r)), block_size=8)
    p0 = pack_worker_tiles(ell0, plan)
    assert p0.live_tiles.sum() == 0
    ell1 = dense_to_block_ell(np.ones((s, r)), block_size=8)
    p1 = pack_worker_tiles(ell1, plan)
    live_slots = (plan.weights != 0).sum()
    # per live slot: one column group of A = (s/8) x (br/8) = 2 x 1 tiles
    assert p1.live_tiles.sum() == live_slots * 2
    assert p1.vals.shape[0] == plan.num_workers


def test_coded_matmul_survivor_refusal():
    plan = make_plan(2, 2, num_workers=6, seed=1)
    dead = np.zeros(6, dtype=bool)  # everyone dead
    with pytest.raises(ValueError):
        plan.with_survivors(dead)
    # the specific failure is a DecodingError (which IS a ValueError), with
    # the rank deficit spelled out
    with pytest.raises(DecodingError, match="rank"):
        plan.with_survivors(dead)
    # a wrong-length mask is a plain usage error
    with pytest.raises(ValueError, match="entries"):
        plan.with_survivors(np.ones(4, dtype=bool))


def _kill_k_keeping_rank(plan, k_dead, seed=0):
    """A survivor mask with k_dead dead workers that keeps M full rank."""
    M = plan.coefficient_matrix()
    d = plan.m * plan.n
    rng = np.random.default_rng(seed)
    for _ in range(200):
        surv = np.ones(plan.num_workers, dtype=bool)
        surv[rng.choice(plan.num_workers, size=k_dead, replace=False)] = False
        if np.linalg.matrix_rank(M * surv[:, None]) >= d:
            return surv
    pytest.skip(f"no full-rank mask with {k_dead} dead workers for this plan")


@pytest.mark.parametrize("k_dead", [1, 2])
def test_with_survivors_decodes_with_dead_workers(k_dead):
    # decode correctness with 1 and 2 dead workers: the re-derived decode
    # matrix must stay an exact left inverse of the masked coefficient rows
    plan = make_plan(2, 2, num_workers=12, seed=4)
    surv = _kill_k_keeping_rank(plan, k_dead)
    p2 = plan.with_survivors(surv)
    M_surv = plan.coefficient_matrix() * surv[:, None]
    np.testing.assert_allclose(p2.decode @ M_surv, np.eye(4), atol=1e-4)
    # dead workers' columns of the decode matrix are irrelevant: their
    # contributions are zeroed on device, so D[:, dead] @ anything must not
    # be needed -- verify decode applied to masked synthetic results is exact
    rng = np.random.default_rng(1)
    blocks = rng.standard_normal((4, 3, 5))
    results = np.einsum("kc,cij->kij", M_surv, blocks)
    np.testing.assert_allclose(
        np.einsum("ck,kij->cij", p2.decode, results), blocks, atol=1e-6)


def test_with_survivors_all_alive_is_identity_plan():
    plan = make_plan(2, 2, num_workers=8, seed=2)
    assert plan.with_survivors(np.ones(8, dtype=bool)) is plan


def test_with_survivors_still_decodes():
    # drop workers one at a time until rank breaks; every surviving plan must
    # still be an exact left-inverse
    plan = make_plan(2, 2, num_workers=8, seed=2)
    M = np.zeros((8, 4))
    for k in range(8):
        for l in range(plan.max_degree):
            if plan.weights[k, l] != 0:
                M[k, plan.cols[k, l]] += plan.weights[k, l]
    surv = np.ones(8, dtype=bool)
    rng = np.random.default_rng(0)
    for kill in rng.permutation(8)[:4]:
        surv2 = surv.copy()
        surv2[kill] = False
        if np.linalg.matrix_rank(M * surv2[:, None]) < 4:
            continue
        p2 = plan.with_survivors(surv2)
        np.testing.assert_allclose(p2.decode @ (M * surv2[:, None]), np.eye(4), atol=1e-4)
        surv = surv2
