import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.coded_matmul import (
    CodedMatmulPlan,
    coded_matmul,
    make_plan,
    uncoded_matmul_reference,
)


def _mesh_1d(name="model"):
    devs = jax.devices()
    return jax.make_mesh((len(devs),), (name,))


def test_make_plan_full_rank_and_padded():
    plan = make_plan(2, 2, num_workers=8, seed=0)
    assert plan.cols.shape == plan.weights.shape == (8, plan.max_degree)
    M = np.zeros((8, 4))
    for k in range(8):
        for l in range(plan.max_degree):
            if plan.weights[k, l] != 0:
                M[k, plan.cols[k, l]] += plan.weights[k, l]
    assert np.linalg.matrix_rank(M) == 4
    # decode really is a left inverse
    np.testing.assert_allclose(plan.decode @ M, np.eye(4), atol=1e-4)


def test_coded_matmul_single_device_mn1():
    # on the single default device only mn=1 is codable (N=1 row spans 1 block)
    mesh = _mesh_1d()
    plan = make_plan(1, 1, num_workers=mesh.shape["model"], max_degree=1, seed=3)
    rng = np.random.default_rng(0)
    s, r, t = 24, 8, 12
    A = jnp.asarray(rng.standard_normal((s, r)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
    C = coded_matmul(A, B, plan, mesh)
    C_ref = uncoded_matmul_reference(A, B)
    np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref), atol=1e-2, rtol=1e-3)


def test_coded_matmul_spmd_8dev_subprocess():
    """Full SPMD check on an 8-device mesh (subprocess so the main pytest
    process keeps the default single-device platform)."""
    import pathlib
    import subprocess
    import sys

    script = pathlib.Path(__file__).parent / "spmd_coded_matmul_check.py"
    env = dict(os.environ, PYTHONPATH=str(pathlib.Path(__file__).parents[1] / "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ALL-OK" in out.stdout


def test_coded_matmul_survivor_refusal():
    plan = make_plan(2, 2, num_workers=6, seed=1)
    dead = np.zeros(6, dtype=bool)  # everyone dead
    with pytest.raises(ValueError):
        plan.with_survivors(dead)


def test_with_survivors_still_decodes():
    # drop workers one at a time until rank breaks; every surviving plan must
    # still be an exact left-inverse
    plan = make_plan(2, 2, num_workers=8, seed=2)
    M = np.zeros((8, 4))
    for k in range(8):
        for l in range(plan.max_degree):
            if plan.weights[k, l] != 0:
                M[k, plan.cols[k, l]] += plan.weights[k, l]
    surv = np.ones(8, dtype=bool)
    rng = np.random.default_rng(0)
    for kill in rng.permutation(8)[:4]:
        surv2 = surv.copy()
        surv2[kill] = False
        if np.linalg.matrix_rank(M * surv2[:, None]) < 4:
            continue
        p2 = plan.with_survivors(surv2)
        np.testing.assert_allclose(p2.decode @ (M * surv2[:, None]), np.eye(4), atol=1e-4)
        surv = surv2
