import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import schemes


def _true_blocks(rng, d, shape=(4, 5)):
    return [rng.random(shape) for _ in range(d)]


def _results_for(code, blocks):
    """Compute every row's result exactly from the generator matrix."""
    M = code.M.toarray()
    return {
        r: sum(M[r, c] * blocks[c] for c in range(code.mn) if M[r, c] != 0.0)
        for r in range(M.shape[0])
    }


@pytest.mark.parametrize("name", ["uncoded", "sparse_code", "lt_code", "sparse_mds",
                                  "polynomial", "mds", "product"])
def test_scheme_end_to_end(name):
    m, n, N = 2, 3, 18
    rng = np.random.default_rng(42)
    ctor = schemes.SCHEMES[name]
    code = ctor(m, n) if name == "uncoded" else ctor(m, n, N)
    d = m * n
    blocks = _true_blocks(rng, d)
    results = _results_for(code, blocks)

    # find a decodable prefix of workers (straggler-free order here)
    workers = list(range(code.num_workers))
    for k in range(1, code.num_workers + 1):
        if code.can_decode(workers[:k]):
            got = code.decode(workers[:k], results)
            for g, w in zip(got, blocks):
                np.testing.assert_allclose(np.asarray(g), w, atol=1e-6)
            return
    pytest.fail(f"{name} never became decodable with all workers")


def test_uncoded_needs_all_workers():
    code = schemes.uncoded(2, 2)
    assert not code.can_decode([0, 1, 2])
    assert code.can_decode([0, 1, 2, 3])


def test_mds_threshold_is_m_workers():
    m, n = 3, 2
    code = schemes.mds_code(m, n, N=6, seed=0)
    assert not code.can_decode([0, 1])
    assert code.can_decode([0, 1, 2])      # any m workers
    assert code.can_decode([3, 4, 5])


def test_polynomial_threshold_exactly_mn():
    m, n = 2, 2
    code = schemes.polynomial_code(m, n, N=8)
    rng = np.random.default_rng(0)
    # any mn rows of the generalized Vandermonde are full rank
    for _ in range(5):
        rows = sorted(rng.choice(8, size=4, replace=False).tolist())
        assert code.can_decode(rows)
    assert not code.can_decode([0, 1, 2])


def test_polynomial_cost_factor_is_mn():
    code = schemes.polynomial_code(3, 4, N=15)
    assert np.all(code.cost_factor == 12.0)


def test_sparse_code_cost_is_row_degree():
    code = schemes.sparse_code(3, 3, N=30, seed=1)
    deg = np.diff(code.M.indptr)
    np.testing.assert_array_equal(code.cost_factor, deg)
    # Wave soliton average degree ~ tau*ln(mn): far below polynomial's mn=9
    assert code.cost_factor.mean() < 6.0


def test_product_code_is_kronecker():
    code = schemes.product_code(2, 2, N=9, seed=0)
    assert code.M.shape[1] == 4
    assert code.num_workers <= 9


def test_lt_code_peel_only_decode():
    rng = np.random.default_rng(3)
    code = schemes.lt_code(2, 2, N=24, seed=3)
    blocks = _true_blocks(rng, 4)
    results = _results_for(code, blocks)
    workers = list(range(code.num_workers))
    for k in range(4, code.num_workers + 1):
        if code.can_decode(workers[:k]):
            got = code.decode(workers[:k], results)
            for g, w in zip(got, blocks):
                np.testing.assert_allclose(g, w, atol=1e-8)
            return
    pytest.skip("LT failed to peel with N=24 (rare but possible)")
