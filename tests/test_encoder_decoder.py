import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import (
    SparseCodeSpec,
    generate_coefficient_matrix,
    make_tasks,
    encode_blocks,
    hybrid_decode,
    gaussian_decode,
    peel_schedule,
    apply_schedule,
)
from repro.core.decoder import DecodingError, decode_matrix
from repro.core.encoder import split_blocks, compute_block_products


def _random_sparse(rng, shape, density=0.05):
    return sp.random(*shape, density=density, random_state=np.random.RandomState(rng.integers(2**31)), format="csr")


def _setup(m=2, n=2, N=8, s=40, r=48, t=48, density=0.2, seed=0, **spec_kw):
    rng = np.random.default_rng(seed)
    A = np.round(rng.random((s, r)) * (rng.random((s, r)) < density) * 10)
    B = np.round(rng.random((s, t)) * (rng.random((s, t)) < density) * 10)
    spec = SparseCodeSpec(m=m, n=n, num_workers=N, seed=seed, **spec_kw)
    M = generate_coefficient_matrix(spec)
    tasks = make_tasks(M)
    A_blocks = split_blocks(A, m)
    B_blocks = split_blocks(B, n)
    results = [encode_blocks(t_, A_blocks, B_blocks, n) for t_ in tasks]
    C = A.T @ B
    return spec, M, results, C, A_blocks, B_blocks


def _assert_blocks_equal(blocks, C, m, n):
    r, t = C.shape
    br, bt = r // m, t // n
    for i in range(m):
        for j in range(n):
            got = blocks[i * n + j]
            if sp.issparse(got):
                got = got.toarray()
            np.testing.assert_allclose(got, C[i * br:(i + 1) * br, j * bt:(j + 1) * bt], atol=1e-6)


def test_paper_motivating_example():
    """Section III-A: the exact 6-worker, m=n=2 example from the paper."""
    M = sp.csr_matrix(np.array([
        [1, 1, 0, 0],   # C1 = A1B1 + A1B2
        [0, 1, 1, 0],   # C2 = A1B2 + A2B1
        [1, 0, 0, 0],   # C3 = A1B1
        [0, 1, 0, 1],   # C4 = A1B2 + A2B2
        [0, 0, 1, 1],   # C5 = A2B1 + A2B2
        [1, 0, 1, 0],   # C6 = A1B1 + A2B1
    ], dtype=float))
    rng = np.random.default_rng(0)
    blocks_true = [rng.random((3, 3)) for _ in range(4)]

    # Case 1: workers {1,3,4,5} finish (0-indexed {0,2,3,4}) -> pure peeling.
    rows = [0, 2, 3, 4]
    results = [sum(M[r, c] * blocks_true[c] for c in range(4)) for r in rows]
    blocks, stats = hybrid_decode(M[rows], results)
    for got, want in zip(blocks, blocks_true):
        np.testing.assert_allclose(got, want, atol=1e-12)
    assert stats.roots == 0, "this case decodes by peeling alone (paper Fig 3a)"

    # Case 2: workers {1,2,5,6} finish -> full rank but NO ripple: rooting.
    rows = [0, 1, 4, 5]
    results = [sum(M[r, c] * blocks_true[c] for c in range(4)) for r in rows]
    blocks, stats = hybrid_decode(M[rows], results)
    for got, want in zip(blocks, blocks_true):
        np.testing.assert_allclose(got, want, atol=1e-10)
    assert stats.roots >= 1, "paper Fig 3b requires a rooting step"


@pytest.mark.parametrize("m,n", [(2, 2), (2, 3), (3, 3), (4, 4)])
def test_hybrid_matches_gaussian(m, n):
    spec, M, results, C, *_ = _setup(m=m, n=n, N=3 * m * n, seed=m * 10 + n)
    # pick a random full-rank subset of rows of size ~ mn + 2
    rng = np.random.default_rng(1)
    d = m * n
    for _ in range(5):
        k = min(d + 2, M.shape[0])
        rows = sorted(rng.choice(M.shape[0], size=k, replace=False))
        sub = M[rows]
        if np.linalg.matrix_rank(sub.toarray()) < d:
            continue
        data = [results[r] for r in rows]
        blocks_h, stats = hybrid_decode(sub, data)
        blocks_g = gaussian_decode(sub, data)
        for bh, bg in zip(blocks_h, blocks_g):
            np.testing.assert_allclose(bh, bg, atol=1e-6)
        _assert_blocks_equal(blocks_h, C, m, n)
        return
    pytest.skip("no full-rank subset found (extremely unlikely)")


def test_decode_recovers_exact_product():
    spec, M, results, C, *_ = _setup(m=3, n=2, N=20, seed=3)
    blocks, stats = hybrid_decode(M, results)
    _assert_blocks_equal(blocks, C, 3, 2)
    assert stats.peels + stats.roots == 6


def test_sparse_blocks_stay_sparse_through_decode():
    """Blocks as scipy.sparse: decode touches only sparse AXPYs."""
    m = n = 2
    rng = np.random.default_rng(0)
    A = sp.random(60, 40, density=0.05, format="csc", random_state=np.random.RandomState(0))
    B = sp.random(60, 44, density=0.05, format="csc", random_state=np.random.RandomState(1))
    spec = SparseCodeSpec(m=m, n=n, num_workers=10, seed=1)
    M = generate_coefficient_matrix(spec)
    A_blocks = split_blocks(A, m)
    B_blocks = split_blocks(B, n)
    results = [encode_blocks(t, A_blocks, B_blocks, n) for t in make_tasks(M)]
    blocks, _ = hybrid_decode(M, results)
    C = (A.T @ B).toarray()
    _assert_blocks_equal(blocks, C, m, n)
    assert all(sp.issparse(b) for b in blocks)


def test_rank_deficient_raises():
    M = sp.csr_matrix(np.array([[1.0, 1.0], [2.0, 2.0], [3.0, 3.0]]))
    with pytest.raises(DecodingError):
        peel_schedule(M)


def test_schedule_is_static_and_replayable():
    spec, M, results, C, *_ = _setup(m=2, n=3, N=14, seed=7)
    sched, stats = peel_schedule(M)
    # replay twice on fresh copies; also on different data with same M
    b1 = apply_schedule(sched, list(results))
    b2 = apply_schedule(sched, list(results))
    for x, y in zip(b1, b2):
        np.testing.assert_allclose(x, y)
    _assert_blocks_equal(b1, C, 2, 3)


def test_decode_matrix_equivalence():
    spec, M, results, C, *_ = _setup(m=2, n=2, N=9, seed=11)
    D = decode_matrix(M)
    stacked = np.stack([np.asarray(r) for r in results])
    blocks = np.einsum("ck,kxy->cxy", D, stacked)
    _assert_blocks_equal(list(blocks), C, 2, 2)


def test_root_pick_heuristics_agree():
    spec, M, results, C, *_ = _setup(m=3, n=3, N=30, seed=5)
    b_rand, s_rand = hybrid_decode(M, results, root_pick="random")
    b_max, s_max = hybrid_decode(M, results, root_pick="max_rows")
    for x, y in zip(b_rand, b_max):
        np.testing.assert_allclose(x, y, atol=1e-6)
