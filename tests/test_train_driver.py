"""End-to-end driver tests: the train CLI runs, checkpoints, survives a
simulated failure, and resumes from the checkpoint."""

import os
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).parents[1] / "src")


def _run(args, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC, TF_CPP_MIN_LOG_LEVEL="2")
    return subprocess.run([sys.executable, "-m", "repro.launch.train"] + args,
                          env=env, capture_output=True, text=True,
                          timeout=timeout)


def test_train_failure_and_resume(tmp_path):
    base = ["--arch", "internlm2-1.8b", "--reduced", "--steps", "12",
            "--batch", "2", "--seq", "32", "--ckpt-every", "5",
            "--ckpt-dir", str(tmp_path)]
    # first run dies at step 8 (after the step-5 checkpoint)
    p1 = _run(base + ["--simulate-failure", "8"])
    assert p1.returncode == 17, p1.stdout + p1.stderr
    assert "SIMULATED FAILURE" in p1.stdout
    # second run resumes from step 5 and completes
    p2 = _run(base)
    assert p2.returncode == 0, p2.stdout + p2.stderr
    assert "resumed from step 5" in p2.stdout
    assert "done" in p2.stdout


def test_train_with_coded_checkpoint(tmp_path):
    p = _run(["--arch", "internlm2-1.8b", "--reduced", "--steps", "6",
              "--batch", "2", "--seq", "32", "--ckpt-every", "5",
              "--coded-ckpt", "--ckpt-dir", str(tmp_path)])
    assert p.returncode == 0, p.stdout + p.stderr
    coded = list(pathlib.Path(tmp_path).glob("*/coded_*/target_*.npz"))
    assert len(coded) >= 24, "coded shards written"
