"""Chaos harness: real subprocess workers, real injected faults.

The process runtime (``runtime.procpool``) must decode through every fault
class the chaos language speaks -- kill, pause past the heartbeat deadline,
slow, drop_result -- whenever the surviving chunk prefixes decode, must fail
fast (naming the faulted workers) when they do not, and must account every
fault in the report's ledger.
"""

import time

import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import schemes
from repro.core.decoder import DecodingError
from repro.core.encoder import split_blocks
from repro.runtime.chaos import (
    Fault,
    FaultPlan,
    FaultRealization,
    drop_result,
    kill,
    pause,
    slow,
)
from repro.runtime.procpool import run_proc_job

M_SPLIT = N_SPLIT = 2


def _data(seed=0):
    A = sp.random(40, 16, density=0.3, format="csc",
                  random_state=np.random.RandomState(seed))
    B = sp.random(40, 20, density=0.3, format="csc",
                  random_state=np.random.RandomState(seed + 1))
    return A, B


def _assert_product(rep, A, B):
    C = (A.T @ B).toarray()
    br, bt = C.shape[0] // M_SPLIT, C.shape[1] // N_SPLIT
    for i in range(M_SPLIT):
        for j in range(N_SPLIT):
            got = rep.blocks[i * N_SPLIT + j]
            got = got.toarray() if sp.issparse(got) else np.asarray(got)
            np.testing.assert_allclose(
                got, C[i * br:(i + 1) * br, j * bt:(j + 1) * bt], atol=1e-8)


def _run(code, plan, *, sleep=0.4, q=4, **kw):
    A, B = _data()
    kw.setdefault("straggler_sleep",
                  {w: sleep for w in range(code.num_workers)})
    rep = run_proc_job(code, split_blocks(A, M_SPLIT),
                       split_blocks(B, N_SPLIT), N_SPLIT,
                       num_chunks=q, plan=plan, timeout=30.0, **kw)
    return rep, A, B


# ----------------------------- the chaos matrix -----------------------------

@pytest.mark.parametrize("fault_for", [
    lambda: kill(1, after_chunk=0),
    lambda: pause(2, after_chunk=0),           # frozen until shutdown
    lambda: slow(3, factor=10.0),
    lambda: drop_result(1, chunk=1),
], ids=["kill", "pause_past_deadline", "slow10x", "drop_result"])
def test_chaos_matrix_recoverable_decodes_and_names_worker(fault_for):
    """Each fault class, injected mid-chunk on a redundant code: the job
    decodes the exact product and the ledger names the faulted worker."""
    fault = fault_for()
    code = schemes.sparse_code(M_SPLIT, N_SPLIT, N=8, seed=4)
    rep, A, B = _run(code, [fault], heartbeat_interval=0.05,
                     heartbeat_deadline=1.0)
    _assert_product(rep, A, B)
    faults = rep.decode_stats["faults"]
    assert fault.worker in faults["workers"]
    assert faults["by_kind"].get(fault.kind) == 1
    assert any(e["kind"] == fault.kind and e["worker"] == fault.worker
               for e in rep.fault_ledger)


def test_kill_at_spawn_unrecoverable_names_worker():
    """uncoded needs every worker: killing one before it delivers anything
    must raise DecodingError naming it, with the crash in the ledger."""
    code = schemes.uncoded(M_SPLIT, N_SPLIT)
    with pytest.raises(DecodingError, match=r"\[1\].*never reported"):
        _run(code, [kill(1)])


def test_pause_past_deadline_unrecoverable_fails_fast():
    """A paused essential worker trips the heartbeat deadline: the master
    gives up promptly (long before the job timeout) and names it."""
    code = schemes.uncoded(M_SPLIT, N_SPLIT)
    t0 = time.perf_counter()
    with pytest.raises(DecodingError, match=r"\[1\].*heartbeat deadline"):
        _run(code, [pause(1)], heartbeat_interval=0.05,
             heartbeat_deadline=0.5)
    assert time.perf_counter() - t0 < 15.0  # deadline, not the 30s timeout


def test_respawn_recovers_essential_worker():
    """One-shot respawn: the killed worker's chunks are reassigned to a
    fresh process, so even a code with zero redundancy completes."""
    code = schemes.uncoded(M_SPLIT, N_SPLIT)
    rep, A, B = _run(code, [kill(1)], respawn=True)
    _assert_product(rep, A, B)
    kinds = [e["kind"] for e in rep.fault_ledger]
    assert kinds == ["kill", "crash_detected", "respawn"]
    crash = rep.fault_ledger[1]
    assert crash["worker"] == 1 and crash["exitcode"] == -9
    # the respawned incarnation redelivered everything: nothing stayed lost
    assert crash["equations_lost"] == 0


def test_drop_result_severs_stream_and_accounts_equations():
    """A dropped chunk message severs the worker's ordered stream; the
    ledger accounts its consumed prefix vs the lost suffix."""
    code = schemes.sparse_code(M_SPLIT, N_SPLIT, N=8, seed=4)
    rep, A, B = _run(code, [drop_result(1, chunk=1)])
    _assert_product(rep, A, B)
    entry = next(e for e in rep.fault_ledger if e["kind"] == "drop_result")
    # sparse_code row of worker 1 spans chunks 0 and 1: chunk 0 was consumed
    # before the chunk-1 message was lost
    assert entry["equations_recovered"] == 1
    assert entry["equations_lost"] == 1
    faults = rep.decode_stats["faults"]
    assert faults["equations_lost"] == 1
    assert faults["equations_recovered"] == 1


def test_proc_job_decode_stats_populated():
    """The process path fills decode_stats like the host paths do, plus the
    fault summary rollup."""
    code = schemes.sparse_code(M_SPLIT, N_SPLIT, N=8, seed=4)
    rep, A, B = _run(code, [kill(1, after_chunk=0)], respawn=False)
    stats = rep.decode_stats
    assert stats["arrivals_consumed"] == rep.chunks_used > 0
    assert stats["tracker_rank"] == code.mn
    assert stats["tracker_rows"] >= stats["tracker_rank"]
    assert stats["exact_checks"] >= 1
    assert stats["faults"]["workers"] == [1]


def test_proc_job_no_faults_clean_run():
    """No plan: the pool is just a transport -- exact product, empty
    ledger, every worker used."""
    code = schemes.sparse_code(M_SPLIT, N_SPLIT, N=6, seed=4)
    rep, A, B = _run(code, None, sleep=0.0, q=2)
    _assert_product(rep, A, B)
    assert rep.fault_ledger == []
    assert rep.decode_stats["faults"]["events"] == 0


# --------------------------- plan validation ---------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(kind="meteor", worker=0)
    with pytest.raises(ValueError, match="factor must be > 1"):
        slow(0, factor=1.0)
    with pytest.raises(ValueError, match="needs the chunk"):
        Fault(kind="drop_result", worker=0)
    plan = FaultPlan.coerce([kill(5), drop_result(0, chunk=3)])
    with pytest.raises(ValueError, match="targets worker 5"):
        plan.validate(num_workers=4, num_chunks=4)
    with pytest.raises(ValueError, match="chunk 3"):
        plan.validate(num_workers=8, num_chunks=2)
    plan.validate(num_workers=8, num_chunks=4)  # geometry fits: no raise
    assert plan.workers == [0, 5]
    assert FaultPlan.coerce(None).faults == ()
    assert FaultPlan.coerce(kill(0)).faults[0].kind == "kill"


def test_proc_job_rejects_plan_outside_geometry():
    code = schemes.uncoded(M_SPLIT, N_SPLIT)
    A, B = _data()
    with pytest.raises(ValueError, match="targets worker 9"):
        run_proc_job(code, split_blocks(A, M_SPLIT),
                     split_blocks(B, N_SPLIT), N_SPLIT,
                     num_chunks=2, plan=[kill(9)])


# ---------------------- the simulator twin of a plan -------------------------

def test_fault_realization_timeline_edits():
    """FaultRealization rewrites the (N, q) chunk timeline exactly as the
    plan prescribes: stretch, cut, shift."""
    work = np.ones((4, 3))
    rng = np.random.default_rng(0)

    t = FaultRealization(plan=FaultPlan.coerce([slow(0, factor=10.0)])) \
        .chunk_completion_times(work, rng)
    np.testing.assert_allclose(t[0], [10.0, 20.0, 30.0])
    np.testing.assert_allclose(t[1], [1.0, 2.0, 3.0])

    t = FaultRealization(plan=FaultPlan.coerce([kill(1, after_chunk=0)])) \
        .chunk_completion_times(work, rng)
    assert t[1, 0] == 1.0 and np.isinf(t[1, 1:]).all()

    t = FaultRealization(plan=FaultPlan.coerce([kill(2)])) \
        .chunk_completion_times(work, rng)
    assert np.isinf(t[2]).all()

    t = FaultRealization(
        plan=FaultPlan.coerce([pause(3, after_chunk=0, duration=5.0)])) \
        .chunk_completion_times(work, rng)
    np.testing.assert_allclose(t[3], [1.0, 7.0, 8.0])

    t = FaultRealization(plan=FaultPlan.coerce([pause(3, after_chunk=1)])) \
        .chunk_completion_times(work, rng)
    assert t[3, 0] == 1.0 and t[3, 1] == 2.0 and np.isinf(t[3, 2])

    t = FaultRealization(plan=FaultPlan.coerce([drop_result(0, chunk=1)])) \
        .chunk_completion_times(work, rng)
    assert t[0, 0] == 1.0 and np.isinf(t[0, 1:]).all()


def test_fault_realization_predicts_simulator_decode():
    """run_coded_job under a FaultRealization reproduces the process pool's
    recovery semantics: the killed worker's lost chunks are routed around."""
    from repro.runtime import run_coded_job

    m, n, N = 2, 2, 8
    rng = np.random.default_rng(1)
    blocks = [rng.random((6, 7)) for _ in range(m * n)]
    code = schemes.sparse_code(m, n, N, seed=4)
    plan = FaultPlan.coerce([kill(1, after_chunk=0)])
    rep = run_coded_job(code, blocks, FaultRealization(plan=plan),
                        rng=rng, num_chunks=4, keep_blocks=True)
    for got, want in zip(rep.blocks, blocks):
        got = got.toarray() if sp.issparse(got) else np.asarray(got)
        np.testing.assert_allclose(got, want, atol=1e-8)
    assert np.isfinite(rep.sim_compute_time)
