"""End-to-end training driver example.

Default: a ~115M-parameter dense LM (same code path as the 10 assigned
archs) for a few hundred steps -- the assignment's "train a ~100M model"
driver.  On this CPU container that is hours; pass --tiny for a 2-minute
demonstration of the identical pipeline (synthetic corpus -> pjit train step
-> async checkpoints -> resume).

  PYTHONPATH=src python examples/train_lm.py --tiny --steps 30

Fault-tolerance demo: run with --simulate-failure N, then re-run the same
command -- training resumes from the last checkpoint.
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import ArchConfig, register
from repro.launch.train import main as train_main

LM_100M = register(ArchConfig(
    name="lm-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=3072,
    vocab_size=32_000,
    source="example driver (~115M params)",
))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--simulate-failure", type=int, default=0)
    args, rest = ap.parse_known_args()

    argv = ["--arch", "lm-100m", "--steps", str(args.steps),
            "--batch", "4", "--seq", "256", "--ckpt-every", "20",
            "--coded-ckpt"]
    if args.tiny:
        argv += ["--reduced"]
    if args.simulate_failure:
        argv += ["--simulate-failure", str(args.simulate_failure)]
    return train_main(argv + rest)


if __name__ == "__main__":
    raise SystemExit(main())
