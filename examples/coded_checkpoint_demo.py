"""Coded checkpointing demo: train, erasure-code the checkpoint with the
(P,S)-sparse code across 12 storage targets, destroy a third of them, and
restore exactly -- the paper's any-K-of-N decodability as fault tolerance.

  PYTHONPATH=src python examples/coded_checkpoint_demo.py
"""

import pathlib
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.models import build
from repro.training import checkpoint as ckpt_lib
from repro.training.data import SyntheticCorpus
from repro.training.optimizer import AdamW
from repro.training.train_step import make_train_step


def main():
    cfg = configs.get("internlm2-1.8b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt))
    corpus = SyntheticCorpus(cfg, 2, 32, seed=0)

    for step in range(5):
        batch = {k: jnp.asarray(v) for k, v in corpus.make_batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
    print(f"trained 5 steps, loss={float(metrics['loss']):.4f}")

    tmp = pathlib.Path(tempfile.mkdtemp(prefix="coded_ckpt_"))
    try:
        manifest = ckpt_lib.save_coded_checkpoint(tmp, 5, params, m=3, n=3,
                                                  num_targets=14)
        print(f"wrote {manifest['num_targets']} coded shards "
              f"(mn={manifest['m']*manifest['n']} data chunks)")

        # destroy 4 of 14 storage targets (10 >= mn = 9 survive)
        for k in (1, 4, 7, 10):
            (tmp / "coded_00000005" / f"target_{k:03d}.npz").unlink()
        survivors = [0, 2, 3, 5, 6, 8, 9, 11, 12, 13]
        print(f"destroyed shards [1, 4, 7, 10]; restoring from {survivors}")

        restored, stats = ckpt_lib.restore_coded_checkpoint(
            tmp, 5, params, available=survivors)
        print(f"decode: {stats.peels} peels, {stats.roots} roots")
        err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                        b.astype(jnp.float32))))
                  for a, b in zip(jax.tree.leaves(params),
                                  jax.tree.leaves(restored)))
        print(f"max restore error: {err:.2e}")
        assert err < 1e-4
        print("OK: checkpoint survived losing 4/12 storage targets")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
