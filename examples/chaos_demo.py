"""Chaos smoke: kill a real worker process mid-chunk, decode anyway.

Workers are spawn-started OS subprocesses (``runtime.procpool``); the fault
plan SIGKILLs worker 1 the moment its first chunk reaches the master, so the
rest of its ordered sub-task stream genuinely never arrives (pipe EOF, exit
code -9).  The master detects the crash, keeps consuming the survivors'
chunks, decodes from the prefixes that made it, and accounts the fault in
the report's ledger -- which this demo asserts, making it the CI chaos gate.

  PYTHONPATH=src python examples/chaos_demo.py
"""

import numpy as np
import scipy.sparse as sp

from repro.core import schemes
from repro.core.encoder import split_blocks
from repro.runtime import run_proc_job
from repro.runtime.chaos import kill


def main():
    m = n = 2
    A = sp.random(40, 16, density=0.3, format="csc",
                  random_state=np.random.RandomState(0))
    B = sp.random(40, 20, density=0.3, format="csc",
                  random_state=np.random.RandomState(1))
    code = schemes.sparse_code(m, n, N=8, seed=4)

    rep = run_proc_job(
        code, split_blocks(A, m), split_blocks(B, n), n,
        num_chunks=4,
        straggler_sleep={w: 0.4 for w in range(code.num_workers)},
        plan=[kill(1, after_chunk=0)],  # SIGKILL mid-stream, for real
        timeout=30.0)

    print(rep.summary())
    for entry in rep.fault_ledger:
        print("  ", entry)

    # the decoded product must be exact despite the crash
    C = (A.T @ B).toarray()
    br, bt = C.shape[0] // m, C.shape[1] // n
    for i in range(m):
        for j in range(n):
            got = rep.blocks[i * n + j]
            got = got.toarray() if sp.issparse(got) else np.asarray(got)
            np.testing.assert_allclose(
                got, C[i * br:(i + 1) * br, j * bt:(j + 1) * bt], atol=1e-8)

    # and the ledger must actually name the fault it recovered from
    kinds = {e["kind"] for e in rep.fault_ledger}
    assert "kill" in kinds and "crash_detected" in kinds, kinds
    assert 1 in {e["worker"] for e in rep.fault_ledger}
    crash = next(e for e in rep.fault_ledger if e["kind"] == "crash_detected")
    assert crash["exitcode"] == -9, crash
    print("killed worker 1 mid-chunk; decoded from survivors: OK")


if __name__ == "__main__":
    main()
