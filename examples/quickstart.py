"""Quickstart: the sparse code end to end in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

1. builds a sparse C = A^T B problem, splits it into m x n = 2 x 3 blocks,
2. codes it across N = 12 workers with the Wave Soliton (P, S)-sparse code,
3. declares two workers stragglers and never waits for them,
4. decodes with the hybrid peeling + rooting decoder (Algorithm 1),
5. checks the result against the direct product.
"""

import numpy as np
import scipy.sparse as sp

from repro.core import (
    SparseCodeSpec, generate_coefficient_matrix, make_tasks, encode_blocks,
    hybrid_decode,
)
from repro.core.encoder import split_blocks


def main():
    rng = np.random.default_rng(0)
    m, n, N = 2, 3, 12
    s, r, t = 4000, 1800, 2400
    A = sp.random(s, r, density=0.01, format="csc",
                  random_state=np.random.RandomState(0))
    B = sp.random(s, t, density=0.01, format="csc",
                  random_state=np.random.RandomState(1))
    print(f"A: {A.shape} nnz={A.nnz}   B: {B.shape} nnz={B.nnz}")

    spec = SparseCodeSpec(m=m, n=n, num_workers=N, distribution="wave_soliton")
    M = generate_coefficient_matrix(spec)
    tasks = make_tasks(M)
    print(f"coefficient matrix: {M.shape}, avg degree "
          f"{M.nnz / N:.2f} (Theta(ln mn) -- the paper's overhead)")

    A_blocks, B_blocks = split_blocks(A, m), split_blocks(B, n)
    results = [encode_blocks(t_, A_blocks, B_blocks, n) for t_ in tasks]

    stragglers = {3, 7}
    finished = [k for k in range(N) if k not in stragglers]
    print(f"workers {sorted(stragglers)} are stragglers -> decoding from "
          f"{len(finished)} results")

    blocks, stats = hybrid_decode(M[finished], [results[k] for k in finished])
    print(f"decode: {stats.peels} peels, {stats.roots} rooting steps, "
          f"{stats.axpys} sparse AXPYs")

    C = (A.T @ B).toarray()
    br, bt = r // m, t // n
    err = max(
        abs(blocks[i * n + j] - C[i*br:(i+1)*br, j*bt:(j+1)*bt]).max()
        for i in range(m) for j in range(n)
    )
    print(f"max abs error vs direct product: {err:.2e}")
    assert err < 1e-8
    print("OK")


if __name__ == "__main__":
    main()
