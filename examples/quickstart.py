"""Quickstart: the coded-matmul API end to end in 60 seconds.

  PYTHONPATH=src python examples/quickstart.py

One scheme registry entry drives BOTH execution paths from the same code
design (``repro.coded``, DESIGN.md section 7):

1. pick the paper's (P, S)-sparse code by name -- ``get_scheme("sparse_code")``;
2. host path: ``scheme.instance(...)`` -> master/worker protocol with two
   declared stragglers, hybrid peeling + rooting decode (Algorithm 1);
3. device path: ``plan(config, ...)`` -> a ``CodedOp`` bound to an 8-device
   SPMD mesh, applied, then rebound to survivors with ``with_survivors``;
4. checks both against the direct product.
"""

import os

# 8 host devices for the SPMD op (must be set before jax initializes)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

import numpy as np
import scipy.sparse as sp

from repro.coded import CodedMatmulConfig, get_scheme, plan, scheme_names
from repro.core.encoder import split_blocks, make_tasks, encode_blocks


def host_path():
    """The paper's protocol: code across 12 workers, never wait for two."""
    m, n, N = 2, 3, 12
    s, r, t = 4000, 1800, 2400
    A = sp.random(s, r, density=0.01, format="csc",
                  random_state=np.random.RandomState(0))
    B = sp.random(s, t, density=0.01, format="csc",
                  random_state=np.random.RandomState(1))
    print(f"A: {A.shape} nnz={A.nnz}   B: {B.shape} nnz={B.nnz}")

    scheme = get_scheme("sparse_code")     # any name in scheme_names()
    code = scheme.instance(m, n, N, seed=0, distribution="wave_soliton")
    print(f"scheme {code.name}: avg degree {code.M.nnz / N:.2f} "
          f"(Theta(ln mn) -- the paper's overhead)")

    A_blocks, B_blocks = split_blocks(A, m), split_blocks(B, n)
    results = [encode_blocks(t_, A_blocks, B_blocks, n)
               for t_ in make_tasks(code.M)]

    stragglers = {3, 7}
    finished = [k for k in range(N) if k not in stragglers]
    print(f"workers {sorted(stragglers)} are stragglers -> decoding from "
          f"{len(finished)} results")
    blocks = code.decode(finished, dict(enumerate(results)))

    C = (A.T @ B).toarray()
    br, bt = r // m, t // n
    err = max(
        abs(blocks[i * n + j] - C[i*br:(i+1)*br, j*bt:(j+1)*bt]).max()
        for i in range(m) for j in range(n)
    )
    print(f"host path max abs error vs direct product: {err:.2e}")
    assert err < 1e-8


def device_path():
    """The same design as an SPMD op: plan -> bind -> apply (-> rebind)."""
    import jax.numpy as jnp

    from repro.core.coded_matmul import uncoded_matmul_reference

    cfg = CodedMatmulConfig(scheme="sparse_code", backend="dense_scan")
    op = plan(cfg, m=2, n=2, num_workers=8, seed=5).bind()  # mesh over all devices
    print(f"device path: {op}")

    rng = np.random.default_rng(0)
    s, r, t = 64, 16, 24
    A = jnp.asarray(rng.standard_normal((s, r)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((s, t)), jnp.float32)
    C_ref = np.asarray(uncoded_matmul_reference(A, B))

    C = np.asarray(op(A, B))
    err = np.abs(C - C_ref).max()
    print(f"all-alive max abs error: {err:.2e}")
    assert err < 1e-2

    # kill a worker whose loss keeps the code decodable, rebind, re-apply
    M = op.plan_.coefficient_matrix()
    for kill in range(op.num_workers):
        surv = np.ones(op.num_workers, dtype=bool)
        surv[kill] = False
        if np.linalg.matrix_rank(M * surv[:, None]) >= 4:
            break
    C2 = np.asarray(op.with_survivors(surv)(A, B))
    err2 = np.abs(C2 - C_ref).max()
    print(f"killed worker {kill}: max abs error {err2:.2e} "
          "(decoded from survivors, no recompute)")
    assert err2 < 1e-2


def main():
    print(f"registered schemes: {', '.join(scheme_names())}")
    host_path()
    device_path()
    print("OK")


if __name__ == "__main__":
    main()
