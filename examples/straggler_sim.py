"""Live master/worker run with real threads, real sparse matmuls and an
injected straggler -- the paper's experimental protocol in miniature
(Section V: workers Isend results, master Waitany's until decodable), plus
the chunked sub-task protocol (DESIGN.md section 8): with ``num_chunks`` > 1
a straggler's *finished* chunks are harvested as decode equations instead of
being discarded with the unfinished task.

  PYTHONPATH=src python examples/straggler_sim.py
"""

import numpy as np
import scipy.sparse as sp

from repro.coded import get_scheme
from repro.core.encoder import split_blocks
from repro.runtime import run_live_job


def main():
    m = n = 3
    s, r, t = 6000, 3000, 3000
    A = sp.random(s, r, density=0.005, format="csc",
                  random_state=np.random.RandomState(2))
    B = sp.random(s, t, density=0.005, format="csc",
                  random_state=np.random.RandomState(3))
    A_blocks, B_blocks = split_blocks(A, m), split_blocks(B, n)

    for name, code, num_chunks in [
        ("sparse_code", get_scheme("sparse_code").instance(m, n, 18, seed=0), 1),
        ("sparse q=3", get_scheme("sparse_code").instance(m, n, 18, seed=0), 3),
        ("uncoded", get_scheme("uncoded").instance(m, n), 1),
    ]:
        # worker 0 sleeps 30s -- with the sparse code the master never waits
        # (chunked: the sleep spreads over the chunks, and any chunk worker 0
        # does finish becomes a usable equation); the uncoded run must wait
        # (we cap the demo by making it 1.5s there)
        sleep = {0: 30.0 if name != "uncoded" else 1.5}
        rep = run_live_job(code, A_blocks, B_blocks, n, straggler_sleep=sleep,
                           num_chunks=num_chunks)
        chunks = (f" ({rep.chunks_used} chunks)" if num_chunks > 1 else "")
        print(f"{name:12s} waited {rep.workers_used}/{rep.num_workers} workers"
              f"{chunks}, "
              f"compute {rep.sim_compute_time:.3f}s decode {rep.decode_wall_time:.3f}s "
              f"total {rep.total_time:.3f}s")

    C = (A.T @ B).toarray()
    print(f"(direct product nnz: {np.count_nonzero(C)})")
    print("straggler never blocked the coded run: OK")


if __name__ == "__main__":
    main()
