"""Serving smoke: two tenants, real subprocess workers, one SIGKILLed.

The CI serving gate.  A small MoE model serves a two-tenant Poisson trace
through ``repro.serving.ServingEngine`` with the shared pool backed by
``runtime.procpool.MuxProcPool`` -- real spawn-started OS subprocesses --
and a chaos plan that SIGKILLs worker 1 after its first delivered chunk.
The coded expert jobs keep decoding from the surviving workers, so the
demo asserts: every request completes, every per-token expert product is
exact (the engine verifies each decoded job against the host product and
fails the request otherwise), the kill is in the fault ledger, and at
least one straggler recovery was recorded.

  PYTHONPATH=src python examples/serve_demo.py
"""

import jax

from repro.configs import ARCH_REGISTRY
from repro.runtime.chaos import kill
from repro.runtime.procpool import MuxProcPool
from repro.serving import SLO, TenantSpec, poisson_trace
from repro.serving.engine import ServingEngine

NUM_WORKERS = 6


def main():
    assert jax.default_backend() == "cpu", "demo is a CPU smoke"
    cfg = ARCH_REGISTRY["qwen3-moe-30b-a3b"].reduced()
    tenants = [
        TenantSpec("interactive", rate=25.0, prompt_len=6, max_new_tokens=2,
                   slo=SLO(ttft=120.0, per_token=60.0)),
        TenantSpec("batch", rate=12.0, prompt_len=10, max_new_tokens=3,
                   slo=SLO(ttft=240.0, per_token=120.0)),
    ]
    reqs = poisson_trace(tenants, horizon=0.2, seed=5, max_requests=6)
    assert len({r.tenant for r in reqs}) == 2, "want both tenants in the trace"

    pool = MuxProcPool(NUM_WORKERS, plan=[kill(1, after_chunk=0)],
                       timeout=60.0)
    eng = ServingEngine(cfg, coded=True, num_workers=NUM_WORKERS,
                        source=pool, n_blocks=4, num_chunks=2, max_batch=3)
    with eng:
        eng.warmup(sorted({r.prompt_len for r in reqs}))
        metrics = eng.run(reqs)

    s = metrics.summary()
    print(f"served {s['requests']} requests from {sorted(s['by_tenant'])}: "
          f"{s['completed']} completed, {s['tokens']} tokens, "
          f"{s['straggler_recoveries']} straggler recoveries")
    kinds = sorted({e["kind"] for e in pool.ledger.entries})
    print("fault ledger kinds:", kinds)

    # every request completed with exact decode despite the killed worker
    assert s["completed"] == s["requests"] == len(reqs), [
        (r.rid, r.error) for r in metrics.requests]
    assert all(r.error is None for r in metrics.requests)
    assert "kill" in kinds, kinds
    assert s["straggler_recoveries"] >= 1, s
    assert s["slo_attainment"] == 1.0, s
    print("OK: all requests completed exactly over a pool with a real "
          "SIGKILLed worker")


if __name__ == "__main__":  # spawn-safe: procpool workers re-import this file
    main()
